"""ChunkedPrefillScheduler — the paper's full scheduling round (§3.1–3.3).

Round semantics (decode-first, §3.1.3):
  1. Reserve capacity for all ongoing decode requests (one token each).
  2. Rank prefill candidates by the configured policy (FCFS / SJF / Aging).
  3. For each candidate in priority order: choose a chunk via the static
     token-budget rule (Eq. 7) or LPRS (Algorithm 1); gate it through APC
     (Eq. 14) when enabled; commit the chunk and update request state.
  4. Requests with remaining prefill return to the queue with updated
     priority (heap update, O(log n)).

The scheduler is execution-agnostic: it emits a ScheduledBatch; the engine
(real JAX execution) or the simulator (calibrated clock) runs it and calls
``on_batch_done``.
"""
from __future__ import annotations

from itertools import islice
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple


from repro.core.apc import APCConfig, APCStats, activity_cap
from repro.core.apc import apply as apc_apply
from repro.core.features import BatchState
from repro.core.lprs import LPRSConfig, predicted_resume_rounds, select_chunk
from repro.core.policies import PrefillQueue, make_policy
from repro.core.request import Request, RequestState
from repro.core.slo import SLOConfig, SLOTracker

if TYPE_CHECKING:  # imported lazily at runtime: tenancy itself imports core
    from repro.tenancy import FairnessState
    from repro.tenancy.tenants import FairnessConfig


@dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "fcfs"              # fcfs | sjf | aging
    alpha: float = 1.0                # aging waiting-time weight (>0)
    beta: float = -0.01               # aging remaining-work weight (<0)
    token_budget: int = 1024          # B_max per round
    max_seqs: int = 128               # S_max sequence slots
    lprs: Optional[LPRSConfig] = None # None = static token-budget chunking
    apc: Optional[APCConfig] = None   # None = APC off
    fairness: Optional["FairnessConfig"] = None  # None = single-tenant queue
    # SLO serving tier: per-tenant TTFT/E2E deadlines drive LPRS targets,
    # victim selection, APC protection, and load shedding.  Requires
    # ``fairness`` (the deadlines live on TenantSpec); None = tier off.
    slo: Optional[SLOConfig] = None
    # cache-aware aging credit: priority bonus per token of the request's
    # context already materialized on the attached pool (held blocks, a
    # host-staged swap record one restore round from runnable, or an indexed
    # prefix-cache match) — near-free work is not starved behind full
    # recomputes by pure arrival-order aging.  Key units per token (the same
    # scale as |beta|); 0.0 disables (legacy ordering, bit-identical).
    cache_credit: float = 0.0
    # tiered KV hierarchy: up to this many swap-ready victims are restored
    # EARLY at the end of each round, with genuinely leftover capacity only
    # (free blocks, free slots, no preemption) — the victim decodes from the
    # next round instead of waiting for a queue pop that congestion may
    # never reach.  0 disables (restores only through the pop path).
    swap_prefetch_depth: int = 0
    # partial swap-in: after this many CONSECUTIVE restore deferrals of a
    # host-resident record, shrink it to its decode-hot tail — the prefix
    # is folded for recompute (chunk-by-chunk, block-clipped) and only the
    # tail's blocks need to be free at once.  None disables.
    partial_restore_after: Optional[int] = None


@dataclass
class ScheduledBatch:
    round_idx: int
    decode_reqs: List[Request] = field(default_factory=list)
    prefill_chunks: List[Tuple[Request, int]] = field(default_factory=list)
    state: BatchState = field(default_factory=BatchState)
    # requests evicted this round to make KV room (blocks freed, prefill
    # re-enqueued for recompute) — the engine must reset their slot state
    preempted: List[Request] = field(default_factory=list)
    # swap-mode preemption traffic this round: ``swapped_out`` victims had
    # their KV staged host-side instead of discarded; ``restored`` requests
    # were swapped back in (decode-resumable).  The MB totals price the
    # transfers in the simulator's cost model.
    swapped_out: List[Request] = field(default_factory=list)
    restored: List[Request] = field(default_factory=list)
    swap_out_mb: float = 0.0
    swap_in_mb: float = 0.0

    @property
    def prefill_tokens(self) -> int:
        return sum(c for _, c in self.prefill_chunks)

    @property
    def decode_tokens(self) -> int:
        return len(self.decode_reqs)

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def n_seqs(self) -> int:
        return len(self.decode_reqs) + len(self.prefill_chunks)

    def is_empty(self) -> bool:
        return self.n_seqs == 0


@dataclass
class SchedulerStats:
    rounds: int = 0
    scheduled_prefill_seqs: int = 0     # Σ per-round count (Table 10)
    scheduled_prefill_tokens: int = 0
    scheduled_decode_tokens: int = 0
    preemptions: int = 0                # KV-pressure evictions (all modes)
    swap_preemptions: int = 0           # ... of which swapped out (not recomputed)
    swap_restores: int = 0              # swapped victims restored (swap-in)
    kv_deferrals: int = 0               # chunks deferred for lack of blocks
    swap_deferrals: int = 0             # restores deferred (SWAPPING/space/slots)
    late_stops: int = 0                 # stop-token terminations applied at drain
    refunded_decode_tokens: int = 0     # over-scheduled decodes unwound by stops
    exports: int = 0                    # requests detached for cross-replica handoff
    sheds: int = 0                      # SLO load shedding (admission + queue)
    failovers: int = 0                  # requests evacuated off this scheduler
    #                                     by replica-failure recovery
    quarantined: int = 0                # non-finite requests terminated
    rolled_back_decode_tokens: int = 0  # undrained tokens discarded by crash
    #                                     or quarantine unwinds (VTC refunded)
    # tiered KV hierarchy (host staging as a managed tier):
    prefetched_restores: int = 0        # restores run early by the prefetcher
    restore_wait_rounds: int = 0        # Σ rounds spent host-staged before restore
    host_demotions: int = 0             # staged records host-evicted under the
    #                                     byte budget (victim folded to recompute)
    partial_restores: int = 0           # tail-only swap-ins (prefix recomputed)
    tail_restored_tokens: int = 0       # tokens restored by partial swap-ins
    tail_aborts: int = 0                # tail records dropped because restore
    #                                     preconditions diverged (cache jump)
    apc: APCStats = field(default_factory=APCStats)

    @property
    def avg_prefill_seqs_per_round(self) -> float:
        return self.scheduled_prefill_seqs / max(self.rounds, 1)

    @property
    def avg_chunk_size(self) -> float:
        # prefill tokens per round (incl. rounds with zero prefill)
        return self.scheduled_prefill_tokens / max(self.rounds, 1)

    @property
    def avg_tokens_per_prefill_seq(self) -> float:
        # Paper's Table 10 "Avg. Prefill Chunk Size": tokens per SCHEDULED
        # prefill sequence — fragmentation shows as values near 1.
        return self.scheduled_prefill_tokens / max(self.scheduled_prefill_seqs, 1)


class ChunkedPrefillScheduler:
    def __init__(
        self,
        cfg: SchedulerConfig,
        *,
        predictor=None,
        kv_pool=None,           # optional KVBlockPool: memory features + booking
        kv_booking: bool = True,  # False: legacy mode, pool is features-only
        shared_vtc=None,        # VirtualTokenCounter shared across replicas
    ):
        if cfg.lprs is not None and predictor is None:
            raise ValueError("LPRS requires a latency predictor")
        self.cfg = cfg
        self.predictor = predictor
        self.kv_pool = kv_pool
        self.kv_booking = kv_booking
        # the credit closure reads self.kv_pool dynamically: attach_kv_pool
        # may run after the queue is built, and a pool-less scheduler (pure
        # simulator) simply scores every candidate 0
        credit_fn = self._cache_credit if cfg.cache_credit else None
        if cfg.fairness is not None:
            from repro.tenancy import FairnessState

            self.fairness: Optional["FairnessState"] = FairnessState(
                cfg.fairness,
                policy_factory=lambda: make_policy(
                    cfg.policy, alpha=cfg.alpha, beta=cfg.beta,
                    credit_fn=credit_fn,
                ),
                vtc=shared_vtc,
            )
            self.queue = self.fairness.queue
        else:
            self.fairness = None
            self.queue: PrefillQueue = make_policy(
                cfg.policy, alpha=cfg.alpha, beta=cfg.beta,
                credit_fn=credit_fn,
            )
        # SLO tier: the tracker projects deadlines/feasibility; the fairness
        # subsystem gains the admission shed gate + fair-queue urgency
        self.slo: Optional[SLOTracker] = None
        if cfg.slo is not None:
            if self.fairness is None:
                raise ValueError(
                    "SchedulerConfig.slo requires fairness: deadlines live on "
                    "TenantSpec (ttft_slo_s / e2e_slo_s)"
                )
            self.slo = SLOTracker(
                cfg.slo, self.fairness.registry, token_budget=cfg.token_budget
            )
            self.fairness.attach_slo(self.slo)
        self._prev_round_busy = False
        self._now = 0.0                 # last schedule() clock (victim ranking)
        # decoding membership is maintained INCREMENTALLY (insert on prefill
        # completion, O(1) pop on finish/preemption) — never rebuilt with a
        # full-population comprehension inside the per-round hot path
        self._decoding: Dict[int, Request] = {}
        self._deferred_this_round: List[Request] = []
        self.stats = SchedulerStats()
        self._round = 0
        self._slot_binder = None
        self._slot_releaser = None
        self._bound_slots: set = set()   # req_ids currently holding a slot
        # swap-out preemption (attach_swap): "recompute" discards victims' KV,
        # "swap" stages it host-side and chooses per victim via the cost model
        self.preemption_mode = "recompute"
        self._swapper = None             # engine hook: gather + slot release
        self._swap_restorer = None       # engine hook: scatter staged KV back
        self._swap_cost = None           # CostModel-like (swap bytes vs FLOPs)
        self._swap_restorer_tail = None  # engine hook: scatter a staged tail
        self._payload_slicer = None      # engine hook: trim payload on shrink
        # per-victim restore telemetry: the round each swap-preemption was
        # stamped (restore_wait_rounds accumulates the diff at restore time)
        # and consecutive restore deferrals (the partial swap-in trigger)
        self._swap_round: Dict[int, int] = {}
        self._restore_defers: Dict[int, int] = {}
        if self._books():
            self._apply_tenant_quotas()

    # -- KV wiring ----------------------------------------------------------
    def attach_kv_pool(self, kv_pool, *, booking: bool = True) -> None:
        """Late-bind a pool (serve loops that construct the scheduler first).
        Tenant quotas only apply when the scheduler books blocks — the legacy
        features-only mode predates quotas and must not enforce them."""
        self.kv_pool = kv_pool
        self.kv_booking = booking
        if self._books():
            self._apply_tenant_quotas()

    def _apply_tenant_quotas(self) -> None:
        """Charge per-tenant KV quotas (TenantSpec.kv_quota_frac) into the pool."""
        if self.fairness is None:
            return
        n_blocks = self.kv_pool.cfg.n_blocks
        for spec in self.fairness.registry:
            frac = getattr(spec, "kv_quota_frac", None)
            if frac:
                self.kv_pool.set_tenant_quota(spec.name, max(1, int(frac * n_blocks)))

    def _books(self) -> bool:
        return self.kv_pool is not None and self.kv_booking

    def _cache_credit(self, req: Request) -> float:
        """Cache-aware aging credit (``cfg.cache_credit`` per resident
        token): evaluated whenever the queue (re-)keys the request."""
        if self.kv_pool is None:
            return 0.0
        return self.cfg.cache_credit * self.kv_pool.resident_tokens(req.req_id)

    # -- engine slot wiring (late binding) -----------------------------------
    def attach_slot_binder(self, binder, releaser=None) -> None:
        """Late engine-slot binding: ``binder(req) -> bool`` is consulted
        before the first chunk of a not-yet-started request is committed —
        True means the request holds an execution slot (bound now or
        earlier); False defers the candidate to a later round.  Queued or
        admission-delayed requests therefore never pin slots.  ``releaser``
        (optional) is told about preemptions so the victim's slot frees
        immediately."""
        self._slot_binder = binder
        self._slot_releaser = releaser

    def attach_swap(self, swapper=None, restorer=None, *, cost_model=None,
                    mode: str = "swap", restorer_tail=None,
                    payload_slicer=None) -> None:
        """Enable swap-out preemption (``mode="swap"``): preemption victims'
        KV is staged host-side and they re-enter the fair queue
        decode-resumable instead of prefill-restart.

        ``swapper(req)`` (engine) gathers the victim's pages device-side,
        starts the async device→host copy, releases the slot, and calls
        ``pool.swap_out`` — when absent (simulator), the scheduler swaps the
        pool's accounting directly with ``ready=True``.  ``restorer(req)``
        scatters the staged payload into freshly allocated pages at swap-in.
        ``cost_model`` decides swap-vs-recompute per victim (swap bytes vs
        recompute FLOPs); with no model attached, swap always wins.

        Partial swap-in hooks (``cfg.partial_restore_after``):
        ``restorer_tail(req, payload, tail_start_blocks)`` scatters a
        tail-shrunk payload behind the re-prefilled prefix;
        ``payload_slicer(payload, tail_start_blocks, n_blocks)`` trims the
        staged arrays when a record is shrunk.  Accounting-only callers
        (the simulator) leave both None."""
        if mode not in ("swap", "recompute"):
            raise ValueError(f"unknown preemption mode {mode!r}")
        self.preemption_mode = mode
        self._swapper = swapper
        self._swap_restorer = restorer
        self._swap_cost = cost_model
        self._swap_restorer_tail = restorer_tail
        self._payload_slicer = payload_slicer

    # -- intake ------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Enqueue a request; returns False if hard-quota admission
        (``admission_policy="reject"``) refused it.  A rejected request is
        marked FINISHED (with no completion timestamps, so latency metrics
        ignore it) so serve loops terminate and callers can release any
        slot/KV resources they reserved for it.  Under the ``queue``
        admission policy an over-budget request is parked in a delay pen and
        enters the fair queue once its tenant's token bucket refills."""
        assert req.state == RequestState.WAITING
        if self.fairness is not None:
            decision = self.fairness.admit(req)
            if not decision.admitted:
                req.state = RequestState.FINISHED
                if decision.shed:
                    # SLO load shedding: the deadline was infeasible on
                    # arrival — shed, not rejected-for-rate (finish_time
                    # stays None either way; metrics split on shed_reason)
                    req.shed_reason = "admission"
                    self.stats.sheds += 1
                return False
            if decision.delayed:
                self.queue.add_delayed(req, decision.ready_at)
                return True
        self.queue.add(req)
        return True

    def submit_handoff(self, req: Request) -> None:
        """Enqueue a request whose staged KV was just imported into this
        scheduler's pool (cross-replica handoff).  Admission is NOT re-run —
        the request was assessed once, at the prefill pool; charging its
        token bucket on both sides of the link would double-bill the tenant.
        The ordinary ``schedule()`` swap-restore path picks it up: it is
        decode-resumable, so zero prefill tokens are ever scheduled for it
        here."""
        assert req.state == RequestState.WAITING and req.swapped, (
            req.state, req.swapped,
        )
        self.queue.add(req)

    def retract_handoff(self, req: Request) -> None:
        """Inverse of ``submit_handoff`` BEFORE the restore ran: the request
        died (late stop applied at the source drain) after its staged KV was
        prefetched into this scheduler's pool.  Remove it from the queue and
        drop the imported staging record + registration — nothing was
        booked, bound, or fairness-charged here yet, so nothing else needs
        unwinding."""
        if req in self.queue:
            self.queue.remove(req)
        if self.kv_pool is not None:
            self.kv_pool.drop_swap(req.req_id)
            self.kv_pool.release(req.req_id)

    def export_request(self, req: Request) -> None:
        """Detach a request from this scheduler without releasing its pool
        state (cross-replica handoff: the caller owns migrating the staged
        KV).  The inverse of ``submit_handoff`` on the source side."""
        self._decoding.pop(req.req_id, None)
        self._bound_slots.discard(req.req_id)
        self._swap_round.pop(req.req_id, None)
        self._restore_defers.pop(req.req_id, None)
        if req in self.queue:
            self.queue.remove(req)
        if self.fairness is not None:
            self.fairness.forget(req)
        self.stats.exports += 1

    def _unwind(self, req: Request, batch: Optional[ScheduledBatch] = None) -> None:
        """Detach ``req`` from everything this scheduler holds for it: decode
        set, engine slot, queue membership, any entries in a scheduled-but-
        not-yet-dispatched ``batch`` (whose phantom booking is refunded from
        the stats), KV blocks AND host-staged swap records, and fairness
        bookkeeping.  The request's own state is left untouched — callers
        decide whether this is a terminal retire (stop/shed) or an
        evacuation (failover re-placement)."""
        self._decoding.pop(req.req_id, None)
        self._bound_slots.discard(req.req_id)
        self._swap_round.pop(req.req_id, None)
        self._restore_defers.pop(req.req_id, None)
        if req in self.queue:
            self.queue.remove(req)
        if batch is not None:
            if req in batch.decode_reqs:
                batch.decode_reqs.remove(req)
                self.stats.scheduled_decode_tokens -= 1
                self.stats.refunded_decode_tokens += 1
            for i, (r, c) in enumerate(batch.prefill_chunks):
                if r.req_id == req.req_id:
                    batch.prefill_chunks.pop(i)
                    self.stats.scheduled_prefill_tokens -= int(c)
                    self.stats.scheduled_prefill_seqs -= 1
                    break
        if self._books():
            # the booking refund: blocks the phantom round allocated go back
            # with everything else the request held; a mid-swap victim's
            # staging entry is dropped instead (no blocks on either side)
            self.kv_pool.drop_swap(req.req_id)
            self.kv_pool.release(req.req_id)
        if self._slot_releaser is not None:
            self._slot_releaser(req)
        if self.fairness is not None:
            self.fairness.forget(req)

    def on_stop(self, req: Request, batch: Optional[ScheduledBatch] = None) -> None:
        """A value-dependent stop (EOS) terminated ``req`` outside the normal
        ``on_batch_done`` path — in a pipelined engine the real token id
        lands one round LATE, so by the time the stop is observable the
        request may already be booked into the next, not-yet-dispatched
        round (``batch``), sitting in the queue as a preemption victim, or
        host-staged mid-swap.  Unwind whatever the over-scheduled round
        booked and retire the request everywhere."""
        self._unwind(req, batch)
        self.stats.late_stops += 1

    def evict_request(self, req: Request, batch: Optional[ScheduledBatch] = None) -> None:
        """Failover evacuation: detach a LIVE request from this scheduler
        entirely (its replica crashed or was declared dead) without marking
        it terminal — the router re-places it on a survivor, either
        decode-resumable from a recovered staging record or re-prefilled
        through the ``preempt()`` fold."""
        self._unwind(req, batch)
        self.stats.failovers += 1

    def requeue_failed(self, req: Request) -> None:
        """Re-enqueue a request this scheduler still owns after a crashed
        round was unwound (the caller already ran ``preempt()`` /
        re-registered its pool entry).  Admission is NOT re-run: the request
        was admitted once and its token bucket already charged — a crash
        must not double-bill the tenant."""
        assert req.state == RequestState.WAITING, req.state
        if req in self.queue:
            self.queue.update(req)
        else:
            self.queue.add(req)

    def refund_rolled_back(self, req: Request, *, first_token: bool = False) -> None:
        """Refund the accounting of ONE rolled-back undrained token (a crash
        or quarantine discarded it before delivery): the VTC charge comes
        back so fleet-wide charge keeps equaling executed-and-surviving
        work, and the scheduled-token stats shed the same token.  A token
        that rode a prefill completion was charged as the first-token bonus
        (not counted in ``scheduled_decode_tokens``), so only the fairness
        side's first-token ledger is decremented for it."""
        if not first_token:
            self.stats.scheduled_decode_tokens -= 1
        self.stats.rolled_back_decode_tokens += 1
        if self.fairness is not None:
            self.fairness.refund_token(req, first_token=first_token)

    @property
    def decoding(self) -> List[Request]:
        """Ongoing decode requests in prefill-completion order (a snapshot —
        membership itself lives in an insertion-ordered dict)."""
        return list(self._decoding.values())

    def has_work(self) -> bool:
        return len(self.queue) > 0 or len(self._decoding) > 0

    # -- one scheduling round -------------------------------------------------
    def schedule(self, now: float) -> ScheduledBatch:
        cfg = self.cfg
        batch = ScheduledBatch(round_idx=self._round)
        self._round += 1
        self.stats.rounds += 1
        self._now = now
        if self.slo is not None:
            # fold the previous round's wall time into the EWMA round cost
            # that prices every deadline projection this round
            self.slo.begin_round(now, self._prev_round_busy)
        if self.fairness is not None:
            self.fairness.on_round(now)
        if self.kv_pool is not None:
            # pool time only moves at scheduling points: TTL'd cache blocks
            # expire here, before this round's bookings
            self.kv_pool.advance_clock(now)

        # 1. decode-first: reserve budget for ongoing decodes.  With a booked
        # KV pool every decode token gets its block here (preempting the
        # youngest block-holder under pressure) — a decode is never executed
        # with unbooked memory.
        decode_candidates = list(islice(
            self._decoding.values(),
            min(len(self._decoding), cfg.max_seqs, cfg.token_budget),
        ))
        scheduled_ids: set = set()      # committed this round: preemption-immune
        if self._books():
            batch.decode_reqs = self._book_decode_blocks(
                decode_candidates, batch, scheduled_ids
            )
        else:
            batch.decode_reqs = decode_candidates
        n_decode = len(batch.decode_reqs)
        committed = n_decode

        st = BatchState(
            decode_tokens=n_decode,
            batch_request_count=n_decode,
            sum_decode_context_len=sum(r.context_len for r in batch.decode_reqs),
            max_decode_context_len=max(
                (r.context_len for r in batch.decode_reqs), default=0
            ),
        )
        if self.kv_pool is not None:
            st.kv_used_mb = self.kv_pool.used_mb
            st.kv_free_mb = self.kv_pool.free_mb
            st.hbm_allocated_mb = self.kv_pool.allocated_mb
            st.hbm_reserved_mb = self.kv_pool.reserved_mb

        # deadline-aware LPRS: the tightest admitted deadline (decode set +
        # queued backlog) replaces the static T* for every chunk search this
        # round — slack is spread over predicted_resume_rounds per request
        slo_target_ms = None
        if (
            self.slo is not None
            and self.slo.cfg.deadline_lprs
            and cfg.lprs is not None
        ):
            slo_target_ms = self.slo.round_target_ms(
                list(batch.decode_reqs) + list(self.queue.requests()),
                now,
                cfg.lprs.target_latency_ms,
            )

        # 2.-3. rank prefill candidates, allocate residual budget in order
        cap = (
            activity_cap(
                cfg.apc,
                n_decode=n_decode,
                max_seqs=cfg.max_seqs,
                token_budget=cfg.token_budget,
                committed=committed,
            )
            if cfg.apc is not None
            else None
        )

        n_active_prefills = 0
        deferred: List[Request] = []
        # popped-but-deferred candidates leave the queue until the round
        # ends; expose them to _pick_victim so a block-holder can't hide
        # from preemption by simply having been scanned earlier this round
        # (with swap-mode's extra deferral states this was a real livelock:
        # a stable pop order kept the only eligible victim in `deferred`
        # every round, so no one could ever make room)
        self._deferred_this_round = deferred
        seq_slots = cfg.max_seqs - n_decode
        blocks = 0
        # slot-exhaustion scan state: once the binder misses, only requests
        # ALREADY holding a slot can still be scheduled this round — scan on
        # until every queued slot-holder has been seen, then stop (never
        # starve a slot-holder, but don't walk a 10k-request backlog either).
        slots_missed = False
        bound_left = len(self._bound_slots - self._decoding.keys())
        MAX_BLOCK_SCAN = 8  # bounded lookahead after APC blocks: keeps O(k log n)
        while committed < cfg.token_budget and seq_slots > 0 and blocks < MAX_BLOCK_SCAN:
            req = self.queue.pop()
            if req is None:
                break

            # SLO load shedding, queue leg: a waiting request whose deadline
            # can no longer be met even at max priority is retired now —
            # burning budget on a guaranteed miss would only push OTHER
            # requests past their deadlines.  (Admission sheds on arrival;
            # this catches deadlines that died while queued or swapped out.)
            if (
                self.slo is not None
                and self.slo.cfg.shed
                and not self.slo.feasible(req, now)
            ):
                self.shed_request(req, reason="deadline")
                continue

            # host-tier demotion fold: the victim's staging record was
            # evicted under the host byte budget after it was swap-preempted
            # — its KV exists on NEITHER tier, so the decode-resumable
            # promise is void.  Fold it (generated tokens into the prompt,
            # vLLM recompute semantics) and let it continue below as an
            # ordinary prefill candidate: a recompute, never a leak.
            if (
                self.kv_pool is not None
                and req.swapped
                and self.kv_pool.swap_state(req.req_id) is None
            ):
                req.preempt()
                self.stats.host_demotions += 1
                self._swap_round.pop(req.req_id, None)
                self._restore_defers.pop(req.req_id, None)

            # swap-out victims come back through the SAME fair queue, but a
            # restore (swap-in) replaces the recompute prefill: one round, not
            # ceil(context/budget).  A mid-flight victim (SWAPPING: its
            # device→host gather has not drained) is deferred WITHOUT
            # touching the slot binder — it must never re-bind a slot in the
            # round (or pipeline window) that is still copying its pages out.
            # (Tail-shrunk records skip this branch: their owner re-prefills
            # the prefix below and restores at the block-exact split.)
            if self.kv_pool is not None and \
                    self.kv_pool.swap_state(req.req_id) is not None and \
                    self.kv_pool.swap_tail_start(req.req_id) == 0:
                if self._try_restore(req, batch, scheduled_ids):
                    if req.remaining_prefill <= 0:
                        # decode-resumable: rejoins the decode set and decodes
                        # from the next round (this round's decode tokens were
                        # already booked); no prefill chunk to size
                        self._decoding[req.req_id] = req
                        continue
                    # mid-prefill victim: fall through and chunk over the
                    # restored KV (binder already consulted by the restore)
                else:
                    self.stats.swap_deferrals += 1
                    self._note_restore_defer(req)
                    deferred.append(req)
                    blocks += 1
                    continue

            # engine-slot gate (late binding): bind BEFORE sizing the chunk —
            # binding may consume a prefix-cache hit, which shrinks
            # remaining_prefill.
            if self._slot_binder is not None:
                if req.req_id in self._bound_slots:
                    bound_left -= 1
                elif slots_missed or not self._slot_binder(req):
                    slots_missed = True
                    deferred.append(req)
                    if bound_left <= 0:
                        break          # no schedulable candidate remains
                    continue
                else:
                    self._bound_slots.add(req.req_id)

            # partial swap-in: a tail-shrunk record keeps the decode-hot
            # tail staged while the owner re-prefills the evicted prefix.
            # Chunks are clipped to the block-exact split point; the moment
            # the prefix lands the staged tail is appended in one restore
            # (prefill_done jumps over it — those positions' KV just
            # scattered in, nothing is recomputed or double-written).
            tail_cap = None
            if self.kv_pool is not None and not req.swapped:
                pool = self.kv_pool
                tail_d = pool.swap_tail_start(req.req_id)
                if tail_d > 0:
                    s = tail_d * pool.cfg.block_size
                    if req.prefill_done > s or \
                            pool.swap_tokens(req.req_id) >= req.prompt_len:
                        # preconditions diverged (a prefix-cache hit at slot
                        # bind jumped past the split): the tail can no longer
                        # land behind a block-exact prefix — drop it and
                        # prefill the remainder normally
                        pool.drop_swap(req.req_id)
                        self.stats.tail_aborts += 1
                        self._swap_round.pop(req.req_id, None)
                    elif req.prefill_done == s:
                        if not self._restore_tail(req, tail_d, batch,
                                                  scheduled_ids):
                            self.stats.swap_deferrals += 1
                            deferred.append(req)
                            blocks += 1
                            continue
                        # tail restored: chunk the (>= 1) remaining prompt
                        # tokens over the rebuilt context
                    else:
                        tail_cap = s - req.prefill_done

            h_i = min(req.remaining_prefill, cfg.token_budget - committed)
            if tail_cap is not None:
                h_i = min(h_i, tail_cap)
            if h_i <= 0:
                deferred.append(req)
                break

            # chunk proposal: LPRS (Algorithm 1) or static rule (Eq. 7)
            if cfg.lprs is not None:
                c = select_chunk(
                    remaining=req.remaining_prefill,
                    committed=committed,
                    token_budget=cfg.token_budget,
                    batch_state=st,
                    processed=req.prefill_done,
                    predictor=self.predictor,
                    cfg=cfg.lprs,
                    target_ms=slo_target_ms,
                )
            else:
                c = h_i

            # APC gate (Eq. 14); a deadline-urgent request's chunk bypasses
            # the cap/min-chunk blocks (SLO tier: a protected tenant's
            # prefill is never blocked below the deadline-feasible chunk)
            if cfg.apc is not None:
                urgent = (
                    self.slo is not None
                    and self.slo.cfg.apc_protect
                    and self.slo.urgent(req, now)
                )
                c = apc_apply(
                    cfg.apc,
                    self.stats.apc,
                    proposed=c,
                    remaining=req.remaining_prefill,
                    upper_bound=h_i,
                    n_active_prefills=n_active_prefills,
                    cap=cap,
                    urgent=urgent,
                )

            if tail_cap is not None:
                # never prefill past the split: the chunk that would cross
                # it instead stops exactly on the block boundary the staged
                # tail restores onto
                c = min(int(c), tail_cap)

            # KV gate: shrink the chunk to what the pool (and the tenant's
            # quota) can actually back RIGHT NOW — chunk-granular allocation.
            # A huge prompt takes whatever blocks are available this round and
            # defers the rest instead of memory-blocking every later arrival.
            if self._books() and c > 0:
                fit = self.kv_pool.max_new_tokens(req.req_id, tenant=req.tenant)
                if fit <= 0 and self._make_room(req, batch, scheduled_ids):
                    fit = self.kv_pool.max_new_tokens(req.req_id, tenant=req.tenant)
                if fit < c:
                    c = min(int(c), int(fit))
                    if c < h_i:
                        self.stats.kv_deferrals += 1

            if c <= 0:
                # zero-progress deferral: a request with no prefill done and
                # no blocks held must not pin its freshly bound slot while
                # blocked (e.g. quota-starved) — unbind, re-bind when it can
                # actually run
                if (self._slot_releaser is not None
                        and req.prefill_done == 0
                        and not (self.kv_pool is not None
                                 and self.kv_pool.tables.get(req.req_id))):
                    self._slot_releaser(req)
                    self._bound_slots.discard(req.req_id)
                deferred.append(req)
                blocks += 1
                # cap blocks are global to the round — no later candidate can
                # pass; min-chunk blocks are per-request, keep scanning a
                # bounded number of candidates.
                if cfg.apc is not None and n_active_prefills >= cap:
                    break
                continue
            blocks = 0

            if self._books():
                self.kv_pool.allocate(req.req_id, int(c), tenant=req.tenant)
                scheduled_ids.add(req.req_id)
            batch.prefill_chunks.append((req, int(c)))
            st = st.with_extra_prefill(int(c), req.prefill_done)
            committed += int(c)
            seq_slots -= 1
            if req.remaining_prefill - c > 0:
                n_active_prefills += 1

        for r in deferred:
            self.queue.add(r)
        self._deferred_this_round = []

        # swap-in prefetch: restore up to ``swap_prefetch_depth`` host-ready
        # victims with whatever capacity this round left over — the cold
        # "restore round" (queue pop under congestion) disappears for them
        self._prefetch_restores(batch, scheduled_ids)

        batch.state = st
        self.stats.scheduled_prefill_seqs += len(batch.prefill_chunks)
        self.stats.scheduled_prefill_tokens += batch.prefill_tokens
        self.stats.scheduled_decode_tokens += batch.decode_tokens
        self._prev_round_busy = not batch.is_empty()
        return batch

    def shed_request(self, req: Request, *, reason: str,
                     batch: Optional[ScheduledBatch] = None) -> None:
        """Terminal retire without service completion: SLO load shedding of a
        projected-infeasible deadline, numerics quarantine, or a request that
        exhausted its failover retries.  Full ``on_stop``-style unwinding
        (queue membership, KV blocks AND host-staged swap records, engine
        slot, fairness bookkeeping, any entries in a scheduled-but-undispatched
        ``batch``).  The request ends FINISHED with ``finish_time`` None and
        ``shed_reason`` set — a shed attainment bucket, never a violation."""
        self._unwind(req, batch)
        req.shed_reason = reason
        req.state = RequestState.FINISHED
        self.stats.sheds += 1

    # -- KV booking / preemption ---------------------------------------------
    def _book_decode_blocks(
        self, candidates: List[Request], batch: ScheduledBatch, scheduled_ids: set
    ) -> List[Request]:
        """Allocate one token of KV per decode candidate, evicting the
        youngest block-holder when the pool (or the tenant quota) is out of
        blocks.  A candidate that cannot be backed is deferred to the next
        round rather than executed unbooked."""
        kept: List[Request] = []
        for r in candidates:
            if r.state != RequestState.DECODING:       # preempted this round
                continue
            if self.kv_pool.can_allocate(r.req_id, 1, tenant=r.tenant) or (
                self._make_room(r, batch, scheduled_ids)
            ):
                self.kv_pool.allocate(r.req_id, 1, tenant=r.tenant)
                kept.append(r)
                scheduled_ids.add(r.req_id)
        return kept

    def _make_room(
        self, req: Request, batch: ScheduledBatch, scheduled_ids: set,
        *, tokens: int = 1,
    ) -> bool:
        """Preempt strictly-younger block-holders until ``req`` can allocate
        ``tokens`` more (True) or no eligible victim remains (False).  When
        the tenant quota — not pool space — is the binding limit, only
        same-tenant victims can help.  Restores pass their full staged length
        (a swapped request holds nothing, so ``blocks_needed`` equals its
        whole restore size)."""
        pool = self.kv_pool
        while not pool.can_allocate(req.req_id, tokens, tenant=req.tenant):
            same_tenant = pool.quota_blocked(req.req_id, tokens, tenant=req.tenant)
            victim = self._pick_victim(
                req, scheduled_ids, tenant=req.tenant if same_tenant else None
            )
            if victim is None:
                return False
            self._preempt(victim, batch)
        return True

    def _try_restore(
        self, req: Request, batch: ScheduledBatch, scheduled_ids: set
    ) -> bool:
        """Swap a victim's staged KV back onto the device: bind a slot,
        allocate fresh blocks (re-charging its tenant quota, preempting
        strictly-younger holders if needed), scatter the payload via the
        engine hook, and resume the request.  Returns False — deferring the
        request untouched — while the swap-out copy is still in flight
        (SWAPPING), or when no slot/blocks are available."""
        pool = self.kv_pool
        if not pool.swap_ready(req.req_id):
            return False               # mid-flight: never re-bind this round
        tokens = pool.swap_tokens(req.req_id)
        bound_here = False
        if self._slot_binder is not None and req.req_id not in self._bound_slots:
            if not self._slot_binder(req):
                return False
            self._bound_slots.add(req.req_id)
            bound_here = True
        if not pool.can_allocate(req.req_id, tokens, tenant=req.tenant) and \
                not self._make_room(req, batch, scheduled_ids, tokens=tokens):
            if bound_here and self._slot_releaser is not None:
                # blocks didn't materialize: don't pin the fresh slot
                self._slot_releaser(req)
                self._bound_slots.discard(req.req_id)
            return False
        if pool.swap_state(req.req_id) is None:
            # making room swap-staged younger victims, and THEIR staging
            # charged the host tier past its budget — the stage-time-LRU
            # eviction landed on the very record being restored.  Nothing
            # left to scatter: defer untouched; next round's demotion fold
            # recomputes this request.
            if bound_here and self._slot_releaser is not None:
                self._slot_releaser(req)
                self._bound_slots.discard(req.req_id)
            return False
        _ids, payload = pool.swap_in(req.req_id, tenant=req.tenant)
        if self._swap_restorer is not None:
            self._swap_restorer(req, payload)
        req.resume()
        scheduled_ids.add(req.req_id)   # restore-immune for this round
        self.stats.swap_restores += 1
        self._note_restored(req.req_id)
        batch.restored.append(req)
        batch.swap_in_mb += tokens * pool.cfg.bytes_per_token / 2**20
        if self.fairness is not None and req.state == RequestState.DECODING:
            # it will never finish a prefill chunk: retire its fair-queue
            # ownership and mark it decode-active again
            self.fairness.on_resume(req)
        return True

    def _note_restored(self, req_id: int) -> None:
        """A restore (full, prefetched, or tail) completed: accumulate the
        rounds this victim spent host-staged and clear its telemetry."""
        stamp = self._swap_round.pop(req_id, None)
        if stamp is not None:
            self.stats.restore_wait_rounds += max(0, self._round - stamp)
        self._restore_defers.pop(req_id, None)

    def _note_restore_defer(self, req: Request) -> None:
        """Count a consecutive restore deferral; past
        ``cfg.partial_restore_after`` of them — with the payload
        host-resident and the block shortfall (not slots) the binding limit
        — shrink the record to the largest tail the pool could back right
        now.  The owner is folded (``preempt()``) and re-prefills the
        evicted prefix chunk-by-chunk; only ``n - d`` blocks ever need to
        be free at once, so fragmentation can't pin the victim host-side
        forever."""
        after = self.cfg.partial_restore_after
        if after is None:
            return
        rid = req.req_id
        n = self._restore_defers.get(rid, 0) + 1
        self._restore_defers[rid] = n
        pool = self.kv_pool
        if n < after or not req.swapped or not pool.swap_ready(rid):
            return
        if pool.can_swap_in(rid, tenant=req.tenant):
            return        # blocked on slots, not memory: shrinking can't help
        bs = pool.cfg.block_size
        tokens = pool.swap_tokens(rid)
        nb = (tokens + bs - 1) // bs
        if nb < 2 or tokens >= req.prompt_len + (req.generated - req.folded_tokens):
            return        # nothing to split / stored length would not fit
        d = nb - max(1, min(pool.allocatable_blocks(), nb - 1))
        pool.shrink_swap_to_tail(rid, d, self._payload_slicer)
        req.preempt()     # fold: the prefix re-prefills from scratch
        self._restore_defers.pop(rid, None)

    def _restore_tail(
        self, req: Request, tail_d: int, batch: ScheduledBatch,
        scheduled_ids: set,
    ) -> bool:
        """Complete a partial swap-in: the owner's re-prefill sits exactly on
        the block split, so append fresh blocks for the staged tail, scatter
        it via the engine hook, and jump ``prefill_done`` over the restored
        positions (>= 1 prompt token always remains — its chunk writes
        genuinely new KV and the completing round samples normally)."""
        pool = self.kv_pool
        tokens = pool.swap_tokens(req.req_id)
        tail_tokens = tokens - tail_d * pool.cfg.block_size
        if not pool.can_swap_in(req.req_id, tenant=req.tenant) and \
                not self._make_room(req, batch, scheduled_ids,
                                    tokens=tail_tokens):
            return False
        if pool.swap_state(req.req_id) is None:
            # room-making swap-outs evicted this tail record off the host
            # tier: the staged tail is gone, so fall back to prefilling the
            # remainder (next round sees tail_start == 0 and chunks on)
            self.stats.tail_aborts += 1
            self._swap_round.pop(req.req_id, None)
            return False
        _ids, payload = pool.swap_in_tail(req.req_id, tenant=req.tenant)
        if self._swap_restorer_tail is not None:
            self._swap_restorer_tail(req, payload, tail_d)
        req.prefill_done = tokens
        scheduled_ids.add(req.req_id)   # restore-immune for this round
        self.stats.swap_restores += 1
        self.stats.partial_restores += 1
        self.stats.tail_restored_tokens += tail_tokens
        self._note_restored(req.req_id)
        batch.restored.append(req)
        batch.swap_in_mb += tail_tokens * pool.cfg.bytes_per_token / 2**20
        return True

    def _prefetch_restores(self, batch: ScheduledBatch,
                           scheduled_ids: set) -> None:
        """End-of-round swap-in prefetch: restore up to
        ``cfg.swap_prefetch_depth`` host-ready victims using strictly
        leftover capacity — free blocks (``can_swap_in``, no ``_make_room``)
        and free slots (a binder miss ends the pass).  A decode-resumable
        victim enters the decode set and decodes from the NEXT round's
        decode-first pass, skipping the cold restore round a congested pop
        path may never have reached; a mid-prefill victim re-queues and
        chunks over its restored KV.  Oldest swap-preemption first."""
        depth = self.cfg.swap_prefetch_depth
        if depth <= 0 or self.kv_pool is None or \
                self.preemption_mode != "swap":
            return
        pool = self.kv_pool
        cands = [
            r for r in self.queue.requests()
            if r.swapped
            and r.req_id not in scheduled_ids
            and pool.swap_ready(r.req_id)
            and pool.swap_tail_start(r.req_id) == 0
        ]
        cands.sort(key=lambda r: (
            self._swap_round.get(r.req_id, self._round),
            r.arrival_time, r.req_id,
        ))
        done = 0
        for r in cands:
            if done >= depth:
                break
            if not pool.can_swap_in(r.req_id, tenant=r.tenant):
                continue               # leftover blocks only: no preemption
            if self._slot_binder is not None and \
                    r.req_id not in self._bound_slots:
                if not self._slot_binder(r):
                    break              # no free slot — none will appear now
                self._bound_slots.add(r.req_id)
            self.queue.remove(r)
            tokens = pool.swap_tokens(r.req_id)
            _ids, payload = pool.swap_in(r.req_id, tenant=r.tenant)
            if self._swap_restorer is not None:
                self._swap_restorer(r, payload)
            r.resume()
            scheduled_ids.add(r.req_id)
            self.stats.swap_restores += 1
            self.stats.prefetched_restores += 1
            self._note_restored(r.req_id)
            batch.restored.append(r)
            batch.swap_in_mb += tokens * pool.cfg.bytes_per_token / 2**20
            if r.remaining_prefill <= 0:
                self._decoding[r.req_id] = r
                if self.fairness is not None:
                    self.fairness.on_resume(r)
            else:
                self.queue.add(r)      # chunk over the restored KV next round
            done += 1

    def _should_swap(self, victim: Request) -> bool:
        """Swap-vs-recompute, per victim: compare the swap transfer cost
        (bytes over the host link, out + back in) against re-prefilling the
        victim's whole context (FLOPs plus per-round overhead across the
        rounds LPRS predicts the recompute takes).  No cost model attached
        (or zero-byte accounting pools): swapping wins."""
        if self.preemption_mode != "swap":
            return False
        pool = self.kv_pool
        tokens = pool.lens.get(victim.req_id, 0)
        if tokens <= 0 or pool.swap_state(victim.req_id) is not None:
            return False
        if not pool.host_can_stage(tokens):
            # host tier pinned full by bytes this pool cannot evict (other
            # pools / the handoff store on a shared tier): recompute instead
            # of asserting inside the stage-time reservation
            return False
        if self._swap_cost is None:
            return True
        swap_ms = self._swap_cost.swap_cost_ms(tokens, pool.cfg.bytes_per_token)
        rounds = predicted_resume_rounds(
            tokens, self.cfg.token_budget, swapped=False
        )
        recompute_ms = self._swap_cost.recompute_cost_ms(tokens) + \
            self._swap_cost.cfg.c0_ms * (rounds - 1)
        return swap_ms <= recompute_ms

    def _pick_victim(
        self, requester: Request, scheduled_ids: set, tenant: Optional[str] = None
    ) -> Optional[Request]:
        """Lowest-priority block-holder: the youngest arrival among decoding
        requests and queued (partially prefilled) requests, excluding anything
        already committed to this round's batch.  Only a STRICTLY younger
        victim is eligible — an older request is never preempted for a newer
        one, which makes eviction thrash-free (total order on arrivals).

        With the SLO tier's ``victim_weighting`` on, eligible victims are
        ranked by projected SLO attainment first (a request already violating
        or infeasible sheds before best-effort traffic; a protected,
        deadline-feasible request sheds last), youngest-arrival within a
        class.  Eligibility itself stays strictly-younger in every mode —
        the thrash-freedom total order is load-bearing."""
        pool = self.kv_pool
        best: Optional[Request] = None
        best_key = None
        candidates = (
            list(self._decoding.values())
            + list(self.queue.requests())
            + list(self._deferred_this_round)
        )
        weighted = self.slo is not None and self.slo.cfg.victim_weighting
        for r in candidates:
            if r.req_id == requester.req_id or r.req_id in scheduled_ids:
                continue
            if tenant is not None and r.tenant != tenant:
                continue
            if not pool.tables.get(r.req_id):
                continue
            if (r.arrival_time, r.req_id) <= (requester.arrival_time, requester.req_id):
                continue
            key = (r.arrival_time, r.req_id)
            if weighted:
                key = (self.slo.victim_class(r, self._now),) + key
            if best_key is None or key > best_key:
                best, best_key = r, key
        return best

    def _preempt(self, victim: Request, batch: ScheduledBatch) -> None:
        """Evict one victim: swap its KV out to host staging (swap mode, when
        the cost model favors it) or free its blocks for recompute."""
        was_decoding = victim.state == RequestState.DECODING
        in_queue = victim in self.queue
        is_delayed = getattr(self.queue, "is_delayed", None)
        if self._should_swap(victim):
            tokens = self.kv_pool.lens.get(victim.req_id, 0)
            if self._swapper is not None:
                # engine path: gather pages + start the async device→host
                # copy + release the slot + pool.swap_out (state SWAPPING —
                # restorable only after the engine's drain finalizes it)
                self._swapper(victim)
            else:
                # accounting-only path (simulator): no real copy to wait for
                self.kv_pool.swap_out(victim.req_id, ready=True)
            victim.swap_preempt()
            self.stats.swap_preemptions += 1
            # restore-wait stamp: every restore path (pop, prefetch, tail)
            # accumulates rounds-host-staged against this round index
            self._swap_round[victim.req_id] = self._round
            batch.swapped_out.append(victim)
            batch.swap_out_mb += tokens * self.kv_pool.cfg.bytes_per_token / 2**20
        else:
            self.kv_pool.release(victim.req_id, keep_registration=True)
            victim.preempt()
            if self._slot_releaser is not None:
                self._slot_releaser(victim)    # slot frees for this very round
        self._bound_slots.discard(victim.req_id)
        self.stats.preemptions += 1
        batch.preempted.append(victim)
        if was_decoding:
            self._decoding.pop(victim.req_id, None)
            self.queue.add(victim)
            if self.fairness is not None:
                self.fairness.on_preempt(victim)
        elif is_delayed is not None and is_delayed(victim):
            pass    # still rate-limit parked: released at its ready time
        elif in_queue:
            self.queue.update(victim)   # remaining_prefill changed: re-key

    # -- post-execution updates ---------------------------------------------
    def on_batch_done(self, batch: ScheduledBatch, now: float) -> None:
        """Apply chunk/token deliveries after the engine executed the batch."""
        for req, c in batch.prefill_chunks:
            req.receive_chunk(c)
            if req.state == RequestState.DECODING:
                # Sarathi semantics: the round that finishes the prefill also
                # produces the first output token (TTFT = prefill completion).
                req.prefill_end_time = now
                req.receive_token(req.next_token, now)
                if req.state == RequestState.DECODING:
                    self._decoding[req.req_id] = req
            else:
                # back to the queue with updated priority (O(log n))
                self.queue.update(req)
        for req in batch.decode_reqs:
            req.receive_token(req.next_token, now)
        for req in batch.decode_reqs + [q for q, _ in batch.prefill_chunks]:
            if req.state == RequestState.FINISHED:
                self._decoding.pop(req.req_id, None)
                self._bound_slots.discard(req.req_id)
                if self._slot_releaser is not None:
                    # release here too (idempotent): callers driving the
                    # scheduler directly — not through serve() — must not
                    # leak finished requests' slots
                    self._slot_releaser(req)
                if self._books():
                    # the pool's lifecycle ends here: finished requests'
                    # blocks drop their references (hashed blocks stay
                    # cached for prefix reuse)
                    self.kv_pool.release(req.req_id)
        if self.fairness is not None:
            # charge the VTC for tokens actually executed this round and
            # retire prefill-complete requests from the fair queue's books
            self.fairness.on_batch_done(batch)
