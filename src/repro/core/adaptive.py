"""Unified adaptive scheduling controller — the paper's §5 future work:
"a unified adaptive scheduling controller can be developed to jointly
coordinate Aging, LPRS, and APC, and to dynamically adjust scheduling
parameters according to changing online workloads."

Three coordinated feedback loops, each on the quantity its mechanism
controls:

  * LPRS target T*: tracks an EWMA percentile of observed PREFILL-carrying
    round latencies — the engine's efficiency point drifts as context
    lengths grow, a fixed T* goes stale.
  * Aging alpha/|beta|: starvation pressure (oldest wait in queue vs a
    bound) raises the waiting-time weight; absent starvation and under high
    prompt-length dispersion the remaining-work weight dominates
    (SJF-leaning for TTFT).  Re-keying the heap is O(n log n), done every
    ``adjust_every`` rounds only.
  * APC L_min: follows the median scheduled chunk so the minimum-progress
    bar stays meaningful as LPRS's chunks shrink/grow with decode load.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.scheduler import ChunkedPrefillScheduler, ScheduledBatch


@dataclass
class AdaptiveConfig:
    adjust_every: int = 50              # rounds between parameter updates
    # T* loop
    target_percentile: float = 60.0
    target_ewma: float = 0.3            # weight of the new estimate
    target_bounds: tuple = (5.0, 1000.0)
    # fairness loop
    starvation_bound_s: float = 30.0    # oldest queue wait before alpha boost
    ratio_step: float = 1.6             # multiplicative alpha/|beta| step
    ratio_bounds: tuple = (0.01, 100.0)
    # APC loop
    lmin_ewma: float = 0.3
    lmin_bounds: tuple = (8, 512)


@dataclass
class ControllerState:
    rounds: int = 0
    round_lat_ms: List[float] = field(default_factory=list)
    chunk_sizes: List[int] = field(default_factory=list)
    adjustments: List[dict] = field(default_factory=list)


class AdaptiveController:
    def __init__(self, scheduler: ChunkedPrefillScheduler,
                 cfg: Optional[AdaptiveConfig] = None):
        self.sched = scheduler
        self.cfg = cfg or AdaptiveConfig()
        self.state = ControllerState()

    # -- observation (call after every executed round) -----------------------
    def observe(self, batch: ScheduledBatch, latency_ms: float, now: float):
        st = self.state
        st.rounds += 1
        if batch.prefill_tokens > 0:
            st.round_lat_ms.append(latency_ms)
            st.chunk_sizes.extend(c for _, c in batch.prefill_chunks)
        if st.rounds % self.cfg.adjust_every == 0:
            self._adjust(now)

    # -- the three loops -------------------------------------------------------
    def _adjust(self, now: float):
        cfg = self.cfg
        sched = self.sched
        record = {"round": self.state.rounds}

        # 1. LPRS target tracks the observed efficiency point
        if sched.cfg.lprs is not None and self.state.round_lat_ms:
            obs = float(np.percentile(
                self.state.round_lat_ms[-200:], cfg.target_percentile
            ))
            old = sched.cfg.lprs.target_latency_ms
            new = (1 - cfg.target_ewma) * old + cfg.target_ewma * obs
            new = float(np.clip(new, *cfg.target_bounds))
            sched.cfg = dataclasses.replace(
                sched.cfg,
                lprs=dataclasses.replace(sched.cfg.lprs, target_latency_ms=new),
            )
            record["t_star_ms"] = new

        # 2. Aging ratio from starvation pressure
        waiting = list(sched.queue.requests())
        if waiting:
            oldest = max(now - r.arrival_time for r in waiting)
            ratio = sched.cfg.alpha / abs(sched.cfg.beta)
            if oldest > cfg.starvation_bound_s:
                ratio *= cfg.ratio_step            # wait term up
            else:
                plens = [r.remaining_prefill for r in waiting]
                if len(plens) >= 4 and np.std(plens) > np.mean(plens):
                    ratio /= cfg.ratio_step        # dispersion: SJF-leaning
            ratio = float(np.clip(ratio, *cfg.ratio_bounds))
            new_beta = -sched.cfg.alpha / ratio
            if abs(new_beta - sched.cfg.beta) / abs(sched.cfg.beta) > 1e-6:
                sched.cfg = dataclasses.replace(sched.cfg, beta=new_beta)
                self._rekey_queue()
                record["alpha_over_beta"] = ratio

        # 3. APC minimum effective progress follows the observed chunks
        if sched.cfg.apc is not None and self.state.chunk_sizes:
            med = float(np.median(self.state.chunk_sizes[-500:]))
            old = sched.cfg.apc.l_min
            new = int(np.clip(
                (1 - cfg.lmin_ewma) * old + cfg.lmin_ewma * max(med, 1.0),
                *cfg.lmin_bounds,
            ))
            if new != old:
                sched.cfg = dataclasses.replace(
                    sched.cfg, apc=dataclasses.replace(sched.cfg.apc, l_min=new)
                )
                record["l_min"] = new

        if len(record) > 1:
            self.state.adjustments.append(record)

    def _rekey_queue(self):
        """Rebuild the heap under the new (alpha, beta) — O(n log n), done
        only every adjust_every rounds."""
        from repro.core.policies import make_policy

        reqs = list(self.sched.queue.requests())
        self.sched.queue = make_policy(
            "aging", alpha=self.sched.cfg.alpha, beta=self.sched.cfg.beta
        )
        for r in reqs:
            self.sched.queue.add(r)
