"""LPRS feature extraction (§3.2.1): 11 raw + 5 derived = 16 features.

TPU adaptation (DESIGN.md §2): the CUDA-allocator features are replaced by
the paged-KV pool + HBM accounting — the TPU-serving analogue of allocator
state.  Feature count and roles are preserved.

Raw (11):
  0 prefill_tokens            total prefill tokens scheduled this round
  1 decode_tokens             total decode tokens in the batch
  2 batch_request_count       active batched requests this round
  3 sum_decode_context_len    cumulative context length of decode requests
  4 max_decode_context_len    max context length among decode requests
  5 prefill_processed_tokens  historical prefill progress of batched prefills
  6 max_prefill_processed     max historical prefill progress
  7 kv_used_mb                KV block pool used (was gpu_mem_used_mb)
  8 kv_free_mb                KV block pool free (was gpu_mem_free_mb)
  9 hbm_allocated_mb          params + KV bytes modelled (was cuda_allocated_mb)
 10 hbm_reserved_mb           total HBM pool (was cuda_reserved_mb)

Derived (5):
 11 bias                      1.0 (fixed launch/sync overhead)
 12 scheduled_tokens          decode_tokens + prefill_tokens
 13 avg_decode_ctx            sum_decode_ctx / max(decode_tokens, 1)
 14 decode_ctx_interaction    decode_tokens * avg_decode_ctx
 15 prefill_interaction       prefill_tokens * prefill_processed_tokens
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

N_RAW = 11
N_FEATURES = 16

FEATURE_NAMES = [
    "prefill_tokens",
    "decode_tokens",
    "batch_request_count",
    "sum_decode_context_len",
    "max_decode_context_len",
    "prefill_processed_tokens",
    "max_prefill_processed_tokens",
    "kv_used_mb",
    "kv_free_mb",
    "hbm_allocated_mb",
    "hbm_reserved_mb",
    "bias",
    "scheduled_tokens",
    "avg_decode_ctx",
    "decode_ctx_interaction",
    "prefill_interaction",
]


@dataclass
class BatchState:
    """Runtime state of one candidate scheduling round."""

    prefill_tokens: int = 0
    decode_tokens: int = 0
    batch_request_count: int = 0
    sum_decode_context_len: int = 0
    max_decode_context_len: int = 0
    prefill_processed_tokens: int = 0
    max_prefill_processed_tokens: int = 0
    kv_used_mb: float = 0.0
    kv_free_mb: float = 0.0
    hbm_allocated_mb: float = 0.0
    hbm_reserved_mb: float = 0.0

    def raw(self) -> np.ndarray:
        return np.array(
            [
                self.prefill_tokens,
                self.decode_tokens,
                self.batch_request_count,
                self.sum_decode_context_len,
                self.max_decode_context_len,
                self.prefill_processed_tokens,
                self.max_prefill_processed_tokens,
                self.kv_used_mb,
                self.kv_free_mb,
                self.hbm_allocated_mb,
                self.hbm_reserved_mb,
            ],
            dtype=np.float64,
        )

    def features(self) -> np.ndarray:
        return derive_features(self.raw())

    def with_extra_prefill(self, chunk: int, processed: int) -> "BatchState":
        """Candidate state if `chunk` more prefill tokens (from a request with
        `processed` historical tokens) joined the batch — the x_{t,i}(c) of
        Eq. 9."""
        return BatchState(
            prefill_tokens=self.prefill_tokens + chunk,
            decode_tokens=self.decode_tokens,
            batch_request_count=self.batch_request_count + 1,
            sum_decode_context_len=self.sum_decode_context_len,
            max_decode_context_len=self.max_decode_context_len,
            prefill_processed_tokens=self.prefill_processed_tokens + processed,
            max_prefill_processed_tokens=max(self.max_prefill_processed_tokens, processed),
            kv_used_mb=self.kv_used_mb,
            kv_free_mb=self.kv_free_mb,
            hbm_allocated_mb=self.hbm_allocated_mb,
            hbm_reserved_mb=self.hbm_reserved_mb,
        )


def derive_features(raw: np.ndarray) -> np.ndarray:
    """raw: (..., 11) -> (..., 16) appending the 5 derived features."""
    raw = np.asarray(raw, dtype=np.float64)
    pf = raw[..., 0]
    dec = raw[..., 1]
    sum_ctx = raw[..., 3]
    pf_hist = raw[..., 5]
    bias = np.ones_like(pf)
    scheduled = dec + pf
    avg_ctx = sum_ctx / np.maximum(dec, 1.0)
    ctx_inter = dec * avg_ctx
    pf_inter = pf * pf_hist
    return np.concatenate(
        [raw, np.stack([bias, scheduled, avg_ctx, ctx_inter, pf_inter], axis=-1)], axis=-1
    )
