"""APC — Active Prefill Control (§3.3): dynamic activity cap, minimum
effective progress, and warm start.

Prevents budget dilution (too many active prefills sharing the residual
budget) and micro-progress (1-token chunks that trivially keep requests
active).  Decision rule Eq. 14 on top of the LPRS-proposed chunk.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class APCConfig:
    c_max: int = 4        # configured max active prefills (C_max)
    l_min: int = 64       # minimum effective chunk (L_min)


@dataclass
class APCStats:
    blocked_by_cap: int = 0
    blocked_by_min_chunk: int = 0
    warm_starts: int = 0
    slo_overrides: int = 0  # urgent chunks that bypassed the cap/min-chunk gates


def activity_cap(
    cfg: APCConfig,
    *,
    n_decode: int,          # |D_t|
    max_seqs: int,          # S_max
    token_budget: int,      # B_max
    committed: int,         # U_t
) -> int:
    """Eq. 12 — C_t = min(C_max, S_max - |D_t|, floor((B_max - U_t)/L_min)),
    clamped to >= 0: an over-committed round (U_t > B_max) or a decode set
    at S_max means *no* prefill slots, not a negative count."""
    return max(
        0,
        min(
            cfg.c_max,
            max_seqs - n_decode,
            (token_budget - committed) // cfg.l_min,
        ),
    )


def min_effective_progress(cfg: APCConfig, remaining: int) -> int:
    """Eq. 13 — m_i = min(r_i, L_min)."""
    return min(remaining, cfg.l_min)


def apply(
    cfg: APCConfig,
    stats: APCStats,
    *,
    proposed: int,          # c_i^* from LPRS (or the token-budget rule)
    remaining: int,         # r_i
    upper_bound: int,       # h_i
    n_active_prefills: int, # |P_t| — unfinished prefills already in this batch
    cap: int,               # C_t from activity_cap()
    urgent: bool = False,   # SLO tier: deadline-critical request (apc_protect)
) -> int:
    """Eq. 14 — returns the final chunk c_i (0 = blocked this round).

    ``urgent`` is the SLO tier's protection valve: a request whose deadline
    is feasible only if served now is never blocked by the activity cap or
    the min-chunk rule — it gets the deadline-feasible chunk regardless.
    """
    m_i = min_effective_progress(cfg, remaining)
    if n_active_prefills < cap and proposed >= m_i and proposed > 0:
        return proposed
    if urgent and upper_bound >= 1:
        stats.slo_overrides += 1
        return proposed if proposed > 0 else min(upper_bound, m_i)
    if proposed < m_i and n_active_prefills == 0 and upper_bound >= 1:
        stats.warm_starts += 1
        return min(upper_bound, m_i)
    if n_active_prefills >= cap:
        stats.blocked_by_cap += 1
    elif proposed < m_i:
        stats.blocked_by_min_chunk += 1
    return 0
