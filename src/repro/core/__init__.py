"""The paper's contribution: fairness-aware, latency-controllable scheduling
for chunked-prefill LLM serving (Aging + LPRS + APC)."""
from repro.core.apc import APCConfig, APCStats, activity_cap, min_effective_progress
from repro.core.features import BatchState, derive_features, FEATURE_NAMES, N_FEATURES
from repro.core.lprs import LPRSConfig, candidate_set, select_chunk
from repro.core.policies import (
    NaiveAgingQueue,
    PrefillQueue,
    aging_priority,
    make_policy,
)
from repro.core.predictor import (
    AnalyticPredictor,
    LatencyPredictor,
    PredictorConfig,
    bucket_and_downsample,
)
from repro.core.request import Request, RequestState
from repro.core.scheduler import (
    ChunkedPrefillScheduler,
    ScheduledBatch,
    SchedulerConfig,
    SchedulerStats,
)

__all__ = [
    "APCConfig", "APCStats", "activity_cap", "min_effective_progress",
    "BatchState", "derive_features", "FEATURE_NAMES", "N_FEATURES",
    "LPRSConfig", "candidate_set", "select_chunk",
    "NaiveAgingQueue", "PrefillQueue", "aging_priority", "make_policy",
    "AnalyticPredictor", "LatencyPredictor", "PredictorConfig", "bucket_and_downsample",
    "Request", "RequestState",
    "ChunkedPrefillScheduler", "ScheduledBatch", "SchedulerConfig", "SchedulerStats",
]
