"""Mamba (S6) mixer — the SSM layer of the Jamba hybrid.

Selective scan runs chunked: an outer ``lax.scan`` over sequence chunks
carries the (B, d_inner, d_state) SSM state; the inner per-chunk scan is
wrapped in ``jax.checkpoint`` so training backward stores only chunk-boundary
states (the same recompute strategy as the reference CUDA kernel).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import dense_init

MAMBA_CHUNK = 256


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm.expand * cfg.d_model
    dt_rank = cfg.ssm.dt_rank or max(1, cfg.d_model // 16)
    return d_inner, dt_rank, cfg.ssm.d_state


def init_mamba(rng, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    d_in, dt_rank, N = mamba_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 6)
    # S4D-real initialization for A
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (d_in, N))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), dt),
        "conv_w": dense_init(ks[1], (d_in, cfg.ssm.conv_width), dt, scale=0.5),
        "conv_b": jnp.zeros((d_in,), dt),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * N), dt),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), dt),
        "dt_bias": jnp.full((d_in,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d), dt),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B, S, d_in); w: (d_in, W); state: (B, W-1, d_in) trailing context.

    Returns (y, new_state) with y[t] = b + sum_j w[:, j] * x[t - W + 1 + j].
    """
    B, S, d_in = x.shape
    W = w.shape[1]
    if state is None:
        state = jnp.zeros((B, W - 1, d_in), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S + W - 1, d_in)
    y = jnp.zeros_like(x)
    for j in range(W):
        y = y + xp[:, j:j + S] * w[None, None, :, j]
    new_state = xp[:, S:]  # last W-1 inputs
    return y + b[None, None, :], new_state


def _ssm_chunk(h0, xs, A):
    """One chunk of the selective scan.  h0: (B, d_in, N);
    xs = (x, dt, Bm, Cm) with x/dt: (B, Q, d_in), Bm/Cm: (B, Q, N)."""

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # (B,d_in),(B,d_in),(B,N),(B,N)
        dA = jnp.exp(dt_t[..., None] * A[None])            # (B,d_in,N)
        h = dA * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    x, dt, Bm, Cm = xs
    h, ys = jax.lax.scan(
        step, h0,
        (x.transpose(1, 0, 2), dt.transpose(1, 0, 2),
         Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2)),
    )
    return h, ys.transpose(1, 0, 2)  # (B, Q, d_in)


def mamba_mixer(p, x, cfg: ModelConfig, state=None, chunk: int = MAMBA_CHUNK):
    """x: (B, S, D) -> (y, new_state).

    state: None (prefill from scratch) or dict(conv=(B,W-1,d_in) in compute
    dtype, ssm=(B,d_in,N) float32).
    """
    B, S, D = x.shape
    d_in, dt_rank, N = mamba_dims(cfg)

    xz = x @ p["in_proj"]                       # (B, S, 2*d_in)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, ("batch", "seq", "ssm_inner"))

    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    proj = xc @ p["x_proj"]                     # (B, S, dt_rank + 2N)
    dt_low = proj[..., :dt_rank]
    Bm = proj[..., dt_rank:dt_rank + N].astype(jnp.float32)
    Cm = proj[..., dt_rank + N:].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_low @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"]
    )                                           # (B, S, d_in) f32
    A = -jnp.exp(p["A_log"])                    # (d_in, N) f32
    xc_f = xc.astype(jnp.float32)

    h0 = jnp.zeros((B, d_in, N), jnp.float32) if state is None else state["ssm"]

    Q = min(chunk, S)
    if S % Q:
        Q = math.gcd(S, Q) or 1

    if S == 1:  # decode fast path
        h, ys = _ssm_chunk(h0, (xc_f, dt, Bm, Cm), A)
    else:
        nC = S // Q
        reshape = lambda a: a.reshape(B, nC, Q, a.shape[-1]).transpose(1, 0, 2, 3)
        xs_c = (reshape(xc_f), reshape(dt), reshape(Bm), reshape(Cm))

        chunk_fn = jax.checkpoint(lambda h, inp: _ssm_chunk(h, inp, A), prevent_cse=False)
        h, ys = jax.lax.scan(chunk_fn, h0, xs_c)
        ys = ys.transpose(1, 0, 2, 3).reshape(B, S, d_in)

    y = ys + xc_f * p["D"][None, None, :]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    out = constrain(out, ("batch", "seq", "embed"))
    new_state = {"conv": new_conv, "ssm": h}
    return out, new_state


def mamba_state_struct(cfg: ModelConfig, batch: int):
    d_in, _, N = mamba_dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm.conv_width - 1, d_in), jnp.dtype(cfg.param_dtype)),
        "ssm": jax.ShapeDtypeStruct((batch, d_in, N), jnp.float32),
    }
