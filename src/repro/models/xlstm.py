"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel prefill) and sLSTM
(scalar memory, sequential recurrence), per arXiv:2405.04517.

Layer pattern (xLSTM[7:1]): one sLSTM per ``slstm_every`` layers, rest mLSTM.
mLSTM uses exponential input gating + logsigmoid forget gating with the
standard log-space stabilizer; prefill runs the chunkwise-parallel form
(quadratic within a chunk, recurrent across chunks) so long-context prefill is
sub-quadratic and decode is O(1) state.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import dense_init, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(rng, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    H = cfg.n_heads
    hd = cfg.resolved_head_dim
    d_in = H * hd
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 8)
    return {
        "norm": jnp.ones((d,), dt),
        "wq": dense_init(ks[0], (d, H, hd), dt),
        "wk": dense_init(ks[1], (d, H, hd), dt),
        "wv": dense_init(ks[2], (d, H, hd), dt),
        "wi": dense_init(ks[3], (d, H), jnp.float32, scale=0.1),
        "wf": dense_init(ks[4], (d, H), jnp.float32, scale=0.1),
        "bf": jnp.full((H,), 3.0, jnp.float32),  # bias forget gate open
        "wog": dense_init(ks[5], (d, d_in), dt),
        "out_norm": jnp.ones((d_in,), dt),
        "down_proj": dense_init(ks[6], (d_in, d), dt),
    }


def _mlstm_chunk(carry, xs, hd: int):
    """Chunkwise-parallel mLSTM step.

    carry: (C (B,H,hd,hd) f32, n (B,H,hd) f32, m (B,H) f32)
    xs: q,k,v (B,Q,H,hd); log_i, log_f (B,Q,H) f32
    Returns new carry and h (B,Q,H,hd).
    """
    C_prev, n_prev, m_prev = carry
    q, k, v, log_i, log_f = xs
    B, Q, H, _ = q.shape
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    lf_cum = jnp.cumsum(log_f, axis=1)                     # (B,Q,H) inclusive
    # intra-chunk decay matrix D[t,s] = lf_cum[t]-lf_cum[s]+log_i[s], s<=t
    Dm = lf_cum[:, :, None, :] - lf_cum[:, None, :, :] + log_i[:, None, :, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Dm = jnp.where(tri[None, :, :, None], Dm, NEG_INF)     # (B,t,s,H)

    inter_scale = lf_cum + m_prev[:, None, :]              # (B,Q,H)
    m_t = jnp.maximum(jnp.max(Dm, axis=2), inter_scale)    # (B,Q,H)
    m_t = jnp.maximum(m_t, -1e30)

    w_intra = jnp.exp(Dm - m_t[:, :, None, :])             # (B,t,s,H)
    w_inter = jnp.exp(inter_scale - m_t)                   # (B,Q,H)

    scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * w_intra
    h_intra = jnp.einsum("btsh,bshd->bthd", scores, vf)
    h_inter = w_inter[..., None] * jnp.einsum("bthd,bhde->bthe", qf, C_prev)

    n_intra = jnp.einsum("btsh,bshd->bthd", w_intra, kf)
    n_t = w_inter[..., None] * n_prev[:, None] + n_intra   # (B,Q,H,hd)

    num = h_inter + h_intra
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bthd,bthd->bth", qf, n_t)), jnp.exp(-m_t)
    )
    h = num / den[..., None]                               # (B,Q,H,hd)

    # end-of-chunk state
    total = lf_cum[:, -1, :]                               # (B,H)
    s_scale = total[:, None, :] - lf_cum + log_i           # (B,Q,H)
    m_new = jnp.maximum(total + m_prev, jnp.max(s_scale, axis=1))
    w_state = jnp.exp(s_scale - m_new[:, None, :])         # (B,Q,H)
    C_new = (
        jnp.exp(total + m_prev - m_new)[:, :, None, None] * C_prev
        + jnp.einsum("bqh,bqhd,bqhe->bhde", w_state, kf, vf)
    )
    n_new = (
        jnp.exp(total + m_prev - m_new)[..., None] * n_prev
        + jnp.einsum("bqh,bqhd->bhd", w_state, kf)
    )
    return (C_new, n_new, m_new), h


def mlstm_mixer(p, x, cfg: ModelConfig, state=None, chunk: int = 0):
    """x: (B, S, D) -> (y, new_state); state = (C, n, m)."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    Q = chunk or cfg.ssm.chunk_size

    h_in = rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h_in, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h_in, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h_in, p["wv"])
    q = constrain(q, ("batch", "seq", "heads", None))
    log_i = jnp.einsum("bsd,dh->bsh", h_in.astype(jnp.float32), p["wi"])
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", h_in.astype(jnp.float32), p["wf"]) + p["bf"]
    )

    if state is None:
        state = (
            jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.full((B, H), 0.0, jnp.float32),
        )

    if S % Q:
        Q = math.gcd(S, Q) or 1
    nC = S // Q
    resh = lambda a: a.reshape(B, nC, Q, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))
    xs = (resh(q), resh(k), resh(v), resh(log_i), resh(log_f))

    body = jax.checkpoint(lambda c, inp: _mlstm_chunk(c, inp, hd), prevent_cse=False)
    new_state, hs = jax.lax.scan(body, state, xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H * hd)

    og = jax.nn.sigmoid(h_in @ p["wog"])
    h = rms_norm(h.astype(x.dtype), p["out_norm"], cfg.norm_eps) * og
    return x + h @ p["down_proj"], new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(rng, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    d_in = H * hd
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 6)
    pf = 4.0 / 3.0
    d_ff = int(pf * d)
    return {
        "norm": jnp.ones((d,), dt),
        "w_gates": dense_init(ks[0], (d, 4, H, hd), jnp.float32, scale=0.1),
        "r_gates": dense_init(ks[1], (4, H, hd, hd), jnp.float32, scale=0.1),
        "b_gates": jnp.zeros((4, H, hd), jnp.float32),
        "out_norm": jnp.ones((d_in,), dt),
        "down_proj": dense_init(ks[2], (d_in, d), dt),
        "ffn_norm": jnp.ones((d,), dt),
        "up_proj": dense_init(ks[3], (d, 2 * d_ff), dt),
        "ffn_down": dense_init(ks[4], (d_ff, d), dt),
    }


def _slstm_step(carry, wx_t, r_gates):
    """carry: (c, n, h, m) each (B, H, hd); wx_t: (B, 4, H, hd)."""
    c, n, h, m = carry
    rec = jnp.einsum("bhd,ghde->bghe", h, r_gates)          # (B,4,H,hd)
    g = wx_t + rec
    i_t, f_t, z_t, o_t = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    m_new = jnp.maximum(f_t + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(z_t)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_mixer(p, x, cfg: ModelConfig, state=None):
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.resolved_head_dim

    h_in = rms_norm(x, p["norm"], cfg.norm_eps)
    wx = jnp.einsum("bsd,dghe->bsghe", h_in.astype(jnp.float32), p["w_gates"])
    wx = wx + p["b_gates"][None, None]

    if state is None:
        z = jnp.zeros((B, H, hd), jnp.float32)
        state = (z, z, z, jnp.full((B, H, hd), NEG_INF, jnp.float32))

    step = lambda c, w: _slstm_step(c, w, p["r_gates"])
    new_state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2, 3, 4))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, H * hd)

    h = rms_norm(h.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    x = x + h @ p["down_proj"]
    # gated FFN (factor 4/3, GeLU) per xLSTM post-up-projection block
    f = rms_norm(x, p["ffn_norm"], cfg.norm_eps) @ p["up_proj"]
    a, b = jnp.split(f, 2, axis=-1)
    x = x + (jax.nn.gelu(a) * b) @ p["ffn_down"]
    return x, new_state


def slstm_state_struct(cfg: ModelConfig, batch: int):
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    s = jax.ShapeDtypeStruct((batch, H, hd), jnp.float32)
    return (s, s, s, s)


def mlstm_state_struct(cfg: ModelConfig, batch: int):
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    return (
        jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32),
        jax.ShapeDtypeStruct((batch, H, hd), jnp.float32),
        jax.ShapeDtypeStruct((batch, H), jnp.float32),
    )


# ---------------------------------------------------------------------------
# full xLSTM LM
# ---------------------------------------------------------------------------


class XLSTMLM:
    """xLSTM[7:1] language model: one sLSTM per ``slstm_every`` layers."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_groups = cfg.n_layers // cfg.ssm.slstm_every
        self.m_per_group = cfg.ssm.slstm_every - 1

    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        k_emb, k_m, k_s, k_head = jax.random.split(rng, 4)
        m_rngs = jax.random.split(k_m, self.n_groups * self.m_per_group).reshape(
            self.n_groups, self.m_per_group, 2
        )
        s_rngs = jax.random.split(k_s, self.n_groups)
        mlstm = jax.vmap(jax.vmap(lambda r: init_mlstm(r, cfg)))(m_rngs)
        slstm = jax.vmap(lambda r: init_slstm(r, cfg))(s_rngs)
        return {
            "embed": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
            "mlstm": mlstm,      # (G, 7, ...)
            "slstm": slstm,      # (G, ...)
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": dense_init(k_head, (cfg.d_model, cfg.vocab_size), dt,
                                  scale=1.0 / math.sqrt(cfg.d_model)),
        }

    def _group_fwd(self, x, gp, states):
        """One group: m_per_group mLSTM blocks then one sLSTM block."""
        cfg = self.cfg
        m_states_new = []
        for j in range(self.m_per_group):
            lp = jax.tree.map(lambda a: a[j], gp["mlstm"])
            st = None if states is None else jax.tree.map(lambda a: a[j], states["mlstm"])
            x, ns = mlstm_mixer(lp, x, cfg, st)
            m_states_new.append(ns)
        st = None if states is None else states["slstm"]
        x, s_new = slstm_mixer(gp["slstm"], x, cfg, st)
        stacked_m = jax.tree.map(lambda *a: jnp.stack(a), *m_states_new)
        return x, {"mlstm": stacked_m, "slstm": s_new}

    def _run(self, params, x, states=None, remat: bool = False):
        def body(carry, xs):
            if states is None:
                gp = xs
                st = None
            else:
                gp, st = xs
            y, ns = self._group_fwd(carry, gp, st)
            return y, ns

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        gp_all = {"mlstm": params["mlstm"], "slstm": params["slstm"]}
        xs = gp_all if states is None else (gp_all, states)
        x, new_states = jax.lax.scan(body, x, xs)
        return x, new_states

    def unembed_weight(self, params):
        return params["lm_head"], "dv"

    def train_hidden(self, params, batch, remat: bool = True):
        x = params["embed"][batch["tokens"]]
        x, _ = self._run(params, x, remat=remat)
        return rms_norm(x, params["final_norm"], self.cfg.norm_eps)

    def train_logits(self, params, batch, remat: bool = True):
        x = self.train_hidden(params, batch, remat)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        return constrain(logits, ("batch", "seq", "vocab"))

    def prefill(self, params, batch):
        x = params["embed"][batch["tokens"]]
        x, states = self._run(params, x)
        x = rms_norm(x[:, -1:], params["final_norm"], self.cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
        return constrain(logits, ("batch", "vocab")), states

    def decode(self, params, tokens, cache, lens):
        x = params["embed"][tokens]
        x, states = self._run(params, x, cache)
        x = rms_norm(x[:, -1], params["final_norm"], self.cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x, params["lm_head"])
        return constrain(logits, ("batch", "vocab")), states

    def cache_struct(self, batch: int, seq_len: int):
        cfg = self.cfg
        G, M = self.n_groups, self.m_per_group
        m = mlstm_state_struct(cfg, batch)
        s = slstm_state_struct(cfg, batch)
        stack = lambda t, *lead: jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(tuple(lead) + a.shape, a.dtype), t
        )
        return {"mlstm": stack(m, G, M), "slstm": stack(s, G)}
