"""Model zoo package.  Lazy exports to avoid import cycles with submodules."""


def __getattr__(name):
    if name in ("Model", "build_model", "chunked_cross_entropy"):
        from repro.models import model as _m

        return getattr(_m, name)
    raise AttributeError(name)


__all__ = ["Model", "build_model", "chunked_cross_entropy"]
