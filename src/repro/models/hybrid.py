"""Jamba-style hybrid: groups of (1 attention + 7 Mamba) layers with MoE FFNs
on alternating layers (4 MoE + 4 dense per group -> 36 MoE layers over 72).

Group pattern (index within group):
  0: attention + dense FFN
  1,3,5,7: mamba + MoE FFN
  2,4,6:   mamba + dense FFN

Parameters are stacked per-group and scanned over groups, keeping the HLO a
single compact loop.  KV cache exists only for the one attention layer per
group ((G, B, S, Hkv, hd)); Mamba layers carry O(1) conv/SSM state, which is
what makes long_500k decode viable.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.mamba import init_mamba, mamba_mixer, mamba_state_struct
from repro.models.transformer import _block_decode, _block_fwd


def _init_mamba_layer(rng, cfg: ModelConfig, use_moe: bool) -> Dict[str, Any]:
    ks = jax.random.split(rng, 3)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "mixer_norm": jnp.ones((cfg.d_model,), dt),
        "mamba": init_mamba(ks[0], cfg),
        "ffn_norm": jnp.ones((cfg.d_model,), dt),
    }
    if use_moe:
        p["moe"] = L.init_moe(ks[1], cfg)
    else:
        p["ffn"] = L.init_ffn(ks[1], cfg.d_model, cfg.d_ff, dt)
    return p


def _init_attn_layer(rng, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(rng, 2)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dt),
        "attn": L.init_attention(ks[0], cfg),
        "ffn_norm": jnp.ones((cfg.d_model,), dt),
        "ffn": L.init_ffn(ks[1], cfg.d_model, cfg.d_ff, dt),
    }


def _mamba_layer_fwd(lp, x, cfg, state=None):
    h = L.rms_norm(x, lp["mixer_norm"], cfg.norm_eps)
    mix, new_state = mamba_mixer(lp["mamba"], h, cfg, state)
    x = x + mix
    h = L.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
    if "moe" in lp:
        moe_fn = L.moe_ffn_scatter if cfg.moe_impl == "scatter" else L.moe_ffn
        x = x + moe_fn(lp["moe"], h, cfg)
    else:
        x = x + L.ffn(lp["ffn"], h)
    return x, new_state


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.attn_every and cfg.n_layers % cfg.attn_every == 0
        self.group = cfg.attn_every              # 8
        self.n_groups = cfg.n_layers // self.group
        self.n_mamba = self.group - 1            # 7
        # within-group mamba positions 1..7; odd positions get MoE
        self.moe_slots = [j for j in range(1, self.group) if j % 2 == 1]
        self.dense_slots = [j for j in range(1, self.group) if j % 2 == 0]

    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        k_emb, k_attn, k_moe, k_dense, k_head = jax.random.split(rng, 5)
        a_rngs = jax.random.split(k_attn, self.n_groups)
        moe_rngs = jax.random.split(k_moe, self.n_groups * len(self.moe_slots)).reshape(
            self.n_groups, len(self.moe_slots), 2
        )
        dense_rngs = jax.random.split(
            k_dense, self.n_groups * len(self.dense_slots)
        ).reshape(self.n_groups, len(self.dense_slots), 2)
        return {
            "embed": L.dense_init(k_emb, (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
            "attn_layers": jax.vmap(lambda r: _init_attn_layer(r, cfg))(a_rngs),
            "mamba_moe": jax.vmap(jax.vmap(lambda r: _init_mamba_layer(r, cfg, True)))(moe_rngs),
            "mamba_dense": jax.vmap(jax.vmap(lambda r: _init_mamba_layer(r, cfg, False)))(dense_rngs),
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dt,
                                    scale=1.0 / math.sqrt(cfg.d_model)),
        }

    # -- group bodies ----------------------------------------------------------
    def _group_fwd(self, x, gp, positions, collect_kv: bool, states=None):
        cfg = self.cfg
        x, kv = _block_fwd(gp["attn"], x, positions, cfg, collect_kv)
        new_states = []
        mi = di = 0
        for j in range(1, self.group):
            if j % 2 == 1:
                lp = jax.tree.map(lambda a: a[mi], gp["moe"])
                mi += 1
            else:
                lp = jax.tree.map(lambda a: a[di], gp["dense"])
                di += 1
            st = None if states is None else jax.tree.map(lambda a, j=j: a[j - 1], states)
            x, ns = _mamba_layer_fwd(lp, x, cfg, st)
            new_states.append(ns)
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_states)
        return x, kv, stacked

    def _run(self, params, x, positions, collect_kv: bool, remat: bool):
        def body(carry, gp):
            y, kv, ms = self._group_fwd(carry, gp, positions, collect_kv)
            return y, (kv, ms) if collect_kv else None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        gps = {
            "attn": params["attn_layers"],
            "moe": params["mamba_moe"],
            "dense": params["mamba_dense"],
        }
        x, ys = jax.lax.scan(body, x, gps)
        return x, ys

    # -- entry points -----------------------------------------------------------
    def unembed_weight(self, params):
        return params["lm_head"], "dv"

    def train_hidden(self, params, batch, remat: bool = True):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        x = constrain(x, ("batch", "seq", "embed"))
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x, _ = self._run(params, x, positions, collect_kv=False, remat=remat)
        return L.rms_norm(x, params["final_norm"], cfg.norm_eps)

    def train_logits(self, params, batch, remat: bool = True):
        x = self.train_hidden(params, batch, remat)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        return constrain(logits, ("batch", "seq", "vocab"))

    def prefill(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x, ((ks, vs), mstates) = self._run(params, x, positions, collect_kv=True, remat=False)
        x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
        cache = {"k": ks, "v": vs, "mamba": mstates}
        return constrain(logits, ("batch", "vocab")), cache

    def decode(self, params, tokens, cache, lens):
        cfg = self.cfg
        x = params["embed"][tokens]

        def body(carry, xs):
            gp, ck, cv, ms = xs
            y, ck, cv, _ = _block_decode(gp["attn"], carry, ck, cv, lens, cfg)
            new_states = []
            mi = di = 0
            for j in range(1, self.group):
                if j % 2 == 1:
                    lp = jax.tree.map(lambda a: a[mi], gp["moe"])
                    mi += 1
                else:
                    lp = jax.tree.map(lambda a: a[di], gp["dense"])
                    di += 1
                st = jax.tree.map(lambda a, j=j: a[j - 1], ms)
                y, ns = _mamba_layer_fwd(lp, y, cfg, st)
                new_states.append(ns)
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_states)
            return y, (ck, cv, stacked)

        gps = {
            "attn": params["attn_layers"],
            "moe": params["mamba_moe"],
            "dense": params["mamba_dense"],
        }
        x, (nk, nv, nm) = jax.lax.scan(body, x, (gps, cache["k"], cache["v"], cache["mamba"]))
        x = L.rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x, params["lm_head"])
        return constrain(logits, ("batch", "vocab")), {"k": nk, "v": nv, "mamba": nm}

    def cache_struct(self, batch: int, seq_len: int):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        hd = cfg.resolved_head_dim
        kv_shape = (self.n_groups, batch, seq_len, cfg.n_kv_heads, hd)
        ms = mamba_state_struct(cfg, batch)
        stacked = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct((self.n_groups, self.n_mamba) + a.shape, a.dtype), ms
        )
        return {
            "k": jax.ShapeDtypeStruct(kv_shape, dt),
            "v": jax.ShapeDtypeStruct(kv_shape, dt),
            "mamba": stacked,
        }
