"""Model factory + unified API.

``build_model(cfg)`` dispatches on the config family and returns a wrapper
exposing a uniform surface:

  init(rng) -> params
  train_hidden / train_logits(params, batch)
  prefill(params, batch) -> (last_logits, cache)
  decode(params, tokens, cache, lens) -> (logits, cache)
  loss(params, batch) -> (scalar, metrics)       # chunked cross-entropy
  cache_struct(batch, seq_len)
  input_specs(shape_spec) -> dict of ShapeDtypeStruct (modality stubs incl.)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import constrain
from repro.models.hybrid import HybridLM
from repro.models.transformer import TransformerLM
from repro.models.whisper import WhisperModel
from repro.models.xlstm import XLSTMLM

XENT_CHUNK = 512


def chunked_cross_entropy(hidden, unembed, kind: str, labels, mask=None, chunk: int = XENT_CHUNK):
    """Cross-entropy fused with the unembedding, chunked over sequence so the
    (B, S, V) logits tensor never materializes in fp32.

    hidden: (B, S, D); unembed: (D, V) if kind == "dv" else (V, D);
    labels: (B, S) int32; mask: (B, S) float or None.
    """
    B, S, D = hidden.shape
    V = unembed.shape[1] if kind == "dv" else unembed.shape[0]
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)

    c = min(chunk, S)
    while S % c:
        c -= 1
    nC = S // c

    def body(acc, xs):
        h, y, m = xs                                   # (B,c,D), (B,c), (B,c)
        eq = "bcd,dv->bcv" if kind == "dv" else "bcd,vd->bcv"
        logits = jnp.einsum(eq, h, unembed).astype(jnp.float32)
        logits = constrain(logits, ("batch", None, "vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)       # (B,c)
        oh = jax.nn.one_hot(y, V, dtype=logits.dtype)
        ll = jnp.einsum("bcv,bcv->bc", oh, logits)
        loss = jnp.sum((logz - ll) * m)
        return (acc[0] + loss, acc[1] + jnp.sum(m)), None

    resh = lambda a: a.reshape(B, nC, c, *a.shape[2:]).swapaxes(0, 1)
    body = jax.checkpoint(body, prevent_cse=False)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)),
        (resh(hidden), resh(labels), resh(mask)),
    )
    return tot / jnp.maximum(cnt, 1.0)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.family in ("dense", "moe", "vlm"):
            self.impl = TransformerLM(cfg)
        elif cfg.family == "hybrid":
            self.impl = HybridLM(cfg)
        elif cfg.family == "ssm":
            self.impl = XLSTMLM(cfg)
        elif cfg.family == "encdec":
            self.impl = WhisperModel(cfg)
        else:
            raise ValueError(f"unknown family {cfg.family!r}")

    # passthrough ------------------------------------------------------------
    def init(self, rng):
        return self.impl.init(rng)

    def init_shape(self, rng=None):
        """Param ShapeDtypeStructs without allocation (for the dry-run)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.impl.init, rng)

    def train_hidden(self, params, batch, remat: bool = True):
        return self.impl.train_hidden(params, batch, remat=remat)

    def train_logits(self, params, batch, remat: bool = True):
        return self.impl.train_logits(params, batch, remat=remat)

    def prefill(self, params, batch):
        from repro.models.layers import attention_phase
        with attention_phase("prefill"):
            return self.impl.prefill(params, batch)

    def decode(self, params, tokens, cache, lens):
        return self.impl.decode(params, tokens, cache, lens)

    def cache_struct(self, batch: int, seq_len: int):
        return self.impl.cache_struct(batch, seq_len)

    # loss ---------------------------------------------------------------------
    def loss(self, params, batch, remat: bool = True):
        hidden = self.train_hidden(params, batch, remat=remat)
        w, kind = self.impl.unembed_weight(params)
        labels = batch["labels"]
        # VLM: hidden includes patch positions at the front; loss on text tail
        if labels.shape[1] != hidden.shape[1]:
            hidden = hidden[:, -labels.shape[1]:]
        loss = chunked_cross_entropy(hidden, w, kind, labels, batch.get("loss_mask"))
        return loss, {"loss": loss}

    # input specs -----------------------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this shape cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        emb_dt = jnp.dtype(cfg.param_dtype)

        if shape.kind == "train":
            specs: Dict[str, Any] = {}
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder.seq_len, cfg.d_model), emb_dt
                )
                specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
                specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            elif cfg.family == "vlm":
                P = cfg.n_patch_tokens
                specs["patch_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model), emb_dt)
                specs["tokens"] = jax.ShapeDtypeStruct((B, S - P), i32)
                specs["labels"] = jax.ShapeDtypeStruct((B, S - P), i32)
            else:
                specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
                specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            return specs

        if shape.kind == "prefill":
            specs = {}
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder.seq_len, cfg.d_model), emb_dt
                )
                specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            elif cfg.family == "vlm":
                P = cfg.n_patch_tokens
                specs["patch_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model), emb_dt)
                specs["tokens"] = jax.ShapeDtypeStruct((B, S - P), i32)
            else:
                specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            return specs

        # decode: one new token against a cache of length S
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "cache": self.cache_struct(B, S),
            "lens": jax.ShapeDtypeStruct((B,), i32),
        }


_MODEL_CACHE: Dict[str, Model] = {}


def build_model(cfg: ModelConfig) -> Model:
    key = cfg.name
    if key not in _MODEL_CACHE or _MODEL_CACHE[key].cfg is not cfg:
        _MODEL_CACHE[key] = Model(cfg)
    return _MODEL_CACHE[key]
