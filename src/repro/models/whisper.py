"""Whisper-style encoder-decoder (audio backbone; conv frontend stubbed).

Per the assignment the modality frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, enc_seq, d_model).  The 32-layer
bidirectional encoder, and the 32-layer decoder with self-attention (causal,
KV cache) + cross-attention (encoder KV computed once at prefill) are real.
Whisper uses sinusoidal absolute positions and GELU MLPs (no RoPE/SwiGLU).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L


def sinusoid_at(positions, d_model: int):
    """positions: any int array -> (..., d_model) sinusoidal embeddings."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)
    ang = pos / jnp.power(10_000.0, dim / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoid(seq_len: int, d_model: int):
    return sinusoid_at(jnp.arange(seq_len), d_model)


def _init_mlp(rng, d: int, d_ff: int, dt):
    k1, k2 = jax.random.split(rng)
    return {
        "w_in": L.dense_init(k1, (d, d_ff), dt),
        "w_out": L.dense_init(k2, (d_ff, d), dt, scale=1.0 / math.sqrt(d_ff)),
    }


def _mlp(p, x):
    h = jax.nn.gelu(x @ p["w_in"])
    h = constrain(h, ("batch", "seq", "mlp"))
    return constrain(h @ p["w_out"], ("batch", "seq", "embed"))


def _init_enc_block(rng, cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(rng)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dt),
        "attn": L.init_attention(k1, cfg),
        "mlp_norm": jnp.ones((cfg.d_model,), dt),
        "mlp": _init_mlp(k2, cfg.d_model, cfg.d_ff, dt),
    }


def _init_dec_block(rng, cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "self_norm": jnp.ones((cfg.d_model,), dt),
        "self_attn": L.init_attention(k1, cfg),
        "cross_norm": jnp.ones((cfg.d_model,), dt),
        "cross_attn": L.init_attention(k2, cfg),
        "mlp_norm": jnp.ones((cfg.d_model,), dt),
        "mlp": _init_mlp(k3, cfg.d_model, cfg.d_ff, dt),
    }


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(rng, 4)
        enc_rngs = jax.random.split(ks[0], cfg.encoder.n_layers)
        dec_rngs = jax.random.split(ks[1], cfg.n_layers)
        return {
            "embed": L.dense_init(ks[2], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
            "enc_blocks": jax.vmap(lambda r: _init_enc_block(r, cfg))(enc_rngs),
            "dec_blocks": jax.vmap(lambda r: _init_dec_block(r, cfg))(dec_rngs),
            "enc_norm": jnp.ones((cfg.d_model,), dt),
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }

    # -- encoder -------------------------------------------------------------
    def encode(self, params, frames, remat: bool = False):
        """frames: (B, enc_seq, D) precomputed frame embeddings (stub frontend)."""
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.param_dtype))
        x = x + sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        x = constrain(x, ("batch", "seq", "embed"))

        def body(carry, lp):
            h = L.rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
            q, k, v = L.qkv_project(lp["attn"], h, cfg, rope=False)
            a = L.attention(q, k, v, causal=False)
            y = carry + L.attn_output(lp["attn"], a, cfg)
            y = y + _mlp(lp["mlp"], L.rms_norm(y, lp["mlp_norm"], cfg.norm_eps))
            return y, None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # -- decoder -------------------------------------------------------------
    def _dec_fwd(self, params, tokens, enc_out, collect_kv: bool,
                 remat: bool = False):
        cfg = self.cfg
        B, S = tokens.shape
        x = params["embed"][tokens]
        x = x + sinusoid(S, cfg.d_model).astype(x.dtype)[None]
        x = constrain(x, ("batch", "seq", "embed"))

        def body(carry, lp):
            h = L.rms_norm(carry, lp["self_norm"], cfg.norm_eps)
            q, k, v = L.qkv_project(lp["self_attn"], h, cfg, rope=False)
            a = L.attention(q, k, v, causal=True)
            y = carry + L.attn_output(lp["self_attn"], a, cfg)
            h2 = L.rms_norm(y, lp["cross_norm"], cfg.norm_eps)
            q2 = jnp.einsum("bsd,dhk->bshk", h2, lp["cross_attn"]["wq"])
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
            a2 = L.attention(q2, ck, cv, causal=False)
            y = y + L.attn_output(lp["cross_attn"], a2, cfg)
            y = y + _mlp(lp["mlp"], L.rms_norm(y, lp["mlp_norm"], cfg.norm_eps))
            ca = ("batch", "cache_seq", "cache_heads", "cache_hd")
            kv = (
                (constrain(k, ca), constrain(v, ca),
                 constrain(ck, ca), constrain(cv, ca))
                if collect_kv else None
            )
            return y, kv

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, kvs = jax.lax.scan(body, x, params["dec_blocks"])
        return L.rms_norm(x, params["final_norm"], cfg.norm_eps), kvs

    def unembed_weight(self, params):
        return params["embed"], "vd"

    def train_hidden(self, params, batch, remat: bool = True):
        enc_out = self.encode(params, batch["frames"], remat=remat)
        x, _ = self._dec_fwd(
            params, batch["tokens"], enc_out, collect_kv=False, remat=remat
        )
        return x

    def train_logits(self, params, batch, remat: bool = True):
        x = self.train_hidden(params, batch, remat)
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return constrain(logits, ("batch", "seq", "vocab"))

    def prefill(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        x, (sk, sv, ck, cv) = self._dec_fwd(params, batch["tokens"], enc_out, collect_kv=True)
        logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"])
        cache = {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}
        return constrain(logits, ("batch", "vocab")), cache

    def decode(self, params, tokens, cache, lens):
        cfg = self.cfg
        B = tokens.shape[0]
        x = params["embed"][tokens]
        x = x + sinusoid_at(lens, cfg.d_model)[:, None].astype(x.dtype)

        def body(carry, xs):
            lp, k_c, v_c, ck, cv = xs
            h = L.rms_norm(carry, lp["self_norm"], cfg.norm_eps)
            q, k_new, v_new = L.qkv_project(lp["self_attn"], h, cfg, rope=False)
            bidx = jnp.arange(B)
            k_c = k_c.at[bidx, lens].set(k_new[:, 0])
            v_c = v_c.at[bidx, lens].set(v_new[:, 0])
            a = L.attention(q, k_c, v_c, q_offset=lens, kv_lens=lens + 1)
            y = carry + L.attn_output(lp["self_attn"], a, cfg)
            h2 = L.rms_norm(y, lp["cross_norm"], cfg.norm_eps)
            q2 = jnp.einsum("bsd,dhk->bshk", h2, lp["cross_attn"]["wq"])
            a2 = L.attention(q2, ck, cv, causal=False)
            y = y + L.attn_output(lp["cross_attn"], a2, cfg)
            y = y + _mlp(lp["mlp"], L.rms_norm(y, lp["mlp_norm"], cfg.norm_eps))
            return y, (k_c, v_c)

        xs = (params["dec_blocks"], cache["self_k"], cache["self_v"],
              cache["cross_k"], cache["cross_v"])
        x, (nk, nv) = jax.lax.scan(body, x, xs)
        x = L.rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,vd->bv", x, params["embed"])
        new_cache = dict(cache, self_k=nk, self_v=nv)
        return constrain(logits, ("batch", "vocab")), new_cache

    def cache_struct(self, batch: int, seq_len: int):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        hd = cfg.resolved_head_dim
        self_shape = (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, hd)
        cross_shape = (cfg.n_layers, batch, cfg.encoder.seq_len, cfg.n_kv_heads, hd)
        return {
            "self_k": jax.ShapeDtypeStruct(self_shape, dt),
            "self_v": jax.ShapeDtypeStruct(self_shape, dt),
            "cross_k": jax.ShapeDtypeStruct(cross_shape, dt),
            "cross_v": jax.ShapeDtypeStruct(cross_shape, dt),
        }
