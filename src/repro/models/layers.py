"""Shared model-zoo primitives (pure JAX, functional).

All functions take explicit param pytrees.  Sharding hints go through
``repro.distributed.sharding.constrain`` which is a no-op unless a mesh +
logical-axis rules context is active, so model code stays mesh-agnostic.
"""
from __future__ import annotations

import math
import threading
from contextlib import contextmanager
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if len(shape) == 3:  # (d, h, hd) fused head projection
        fan_in = shape[0]
    s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(rng, shape, jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, causal offset, sliding window, padded-cache masking)
# ---------------------------------------------------------------------------


INVALID_POS = -(1 << 30)  # sentinel for unwritten ring-buffer slots

# 'auto' (default, §Perf-tuned): TRAIN uses the flash blocked-softmax path
# (bounded backward footprint for every arch incl. unsharded-head ones);
# PREFILL uses the exact chunked path (no backward -> footprint bounded by
# one chunk row, and ~17% less HLO-level HBM traffic than flash's carry
# rescaling).  'flash' / 'naive' force one implementation (tests, A/B).
ATTN_IMPL = "auto"

_attn_phase = threading.local()


@contextmanager
def attention_phase(phase: str):
    """'train' (default) or 'prefill' — set by Model entry points."""
    prev = getattr(_attn_phase, "v", "train")
    _attn_phase.v = phase
    try:
        yield
    finally:
        _attn_phase.v = prev

# block sizes tuned in the §Perf loop: boundary/carry traffic of the block
# loop scales ~1/block_k; (1024, 4096) cut the memory term 20% on
# mixtral train_4k vs (512, 1024) with no compute/collective change
FLASH_BLOCK_Q = 1024
FLASH_BLOCK_K = 4096


def attention(q, k, v, **kw):
    # decode (Sq == 1) has no S^2 blow-up, and the flash block-reshape of a
    # sequence-sharded KV cache forces an SPMD full-remat — keep decode on
    # the exact path (GSPMD turns its softmax reductions into the small
    # flash-decode style partial-max/sum all-reduces).
    if q.shape[1] == 1 or ATTN_IMPL == "naive":
        return attention_naive(q, k, v, **kw)
    if ATTN_IMPL == "flash":
        return flash_attention(q, k, v, **kw)
    # auto: exact-chunked for prefill, flash for train
    if getattr(_attn_phase, "v", "train") == "prefill":
        return attention_naive(q, k, v, **kw)
    return flash_attention(q, k, v, **kw)


def attention_naive(
    q,                      # (B, Sq, Hq, hd)
    k,                      # (B, Skv, Hkv, hd)
    v,                      # (B, Skv, Hkv, hd)
    *,
    q_offset=0,             # scalar or (B,): absolute position of q[:, 0]
    kv_lens=None,           # (B,) valid kv length (padded caches); None = all valid
    causal: bool = True,
    sliding_window: int = 0,
    kv_positions=None,      # (B, Skv) absolute key positions (ring buffers)
):
    """Reference GQA attention with flexible masking.

    Positions: query i has absolute position q_offset + i; key j has absolute
    position j unless ``kv_positions`` is given (SWA ring buffers, where slots
    hold non-contiguous positions and INVALID_POS marks unwritten slots).
    Causal mask admits key_pos <= query_pos; sliding window additionally
    requires key_pos > query_pos - window.
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    g = Hq // Hkv

    # MXU semantics: bf16 operands, f32 accumulation via preferred_element_type.
    # (Never .astype(f32) the K/V cache — XLA hoists the convert above the
    # layer scan and materializes an f32 copy of the whole cache in HBM.)
    qf = (q * (1.0 / math.sqrt(hd))).astype(q.dtype).reshape(B, Sq, Hkv, g, hd)

    q_off = jnp.asarray(q_offset)
    q_pos = jnp.arange(Sq)[None, :] + (q_off[:, None] if q_off.ndim else q_off)
    q_pos = jnp.broadcast_to(q_pos, (B, Sq))
    if kv_positions is None:
        k_pos = jnp.broadcast_to(jnp.arange(Skv)[None, :], (B, Skv))
    else:
        k_pos = kv_positions

    def block(q_blk, q_pos_blk):
        # q_blk: (B, Qc, Hkv, g, hd); exact softmax over full Skv
        scores = jnp.einsum(
            "bqhgd,bkhd->bhgqk", q_blk, k, preferred_element_type=jnp.float32
        )
        mask = jnp.ones((B, q_blk.shape[1], Skv), dtype=bool)
        if causal:
            mask &= k_pos[:, None, :] <= q_pos_blk[:, :, None]
        if sliding_window:
            mask &= k_pos[:, None, :] > (q_pos_blk[:, :, None] - sliding_window)
        if kv_lens is not None:
            mask &= jnp.arange(Skv)[None, None, :] < kv_lens[:, None, None]
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum(
            "bhgqk,bkhd->bqhgd", probs, v, preferred_element_type=jnp.float32
        )

    Qc = _pick_chunk(Sq)
    if Qc == Sq:
        out = block(qf, q_pos)
    else:
        nQ = Sq // Qc
        q_c = qf.reshape(B, nQ, Qc, Hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
        p_c = q_pos.reshape(B, nQ, Qc).transpose(1, 0, 2)
        out = jax.lax.map(lambda ab: block(*ab), (q_c, p_c))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hkv, g, hd)
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def _pick_chunk(s: int, target: int = 512) -> int:
    """Largest divisor of s that is <= target (bounds attention score temps)."""
    if s <= target:
        return s
    for c in range(target, 0, -1):
        if s % c == 0:
            return c
    return s


def flash_attention(
    q,                      # (B, Sq, Hq, hd)
    k,                      # (B, Skv, Hkv, hd)
    v,                      # (B, Skv, Hkv, hd)
    *,
    q_offset=0,
    kv_lens=None,
    causal: bool = True,
    sliding_window: int = 0,
    kv_positions=None,
    block_q: int = 0,
    block_k: int = 0,
):
    """Blocked online-softmax attention — same semantics as
    :func:`attention_naive`, but never materializes the (Sq, Skv) score
    matrix: an outer ``lax.map`` over Q chunks and an inner ``lax.scan`` over
    KV blocks carry running (m, l, acc) in f32.  This is the jnp analogue of
    the Pallas kernels (kernels/chunked_prefill_attention.py) and gives XLA a
    program whose HBM traffic is O(S) per row instead of O(S^2)."""
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    g = Hq // Hkv

    qf = (q * (1.0 / math.sqrt(hd))).astype(q.dtype).reshape(B, Sq, Hkv, g, hd)

    q_off = jnp.asarray(q_offset)
    q_pos = jnp.arange(Sq)[None, :] + (q_off[:, None] if q_off.ndim else q_off)
    q_pos = jnp.broadcast_to(q_pos, (B, Sq))
    if kv_positions is None:
        k_pos_all = jnp.broadcast_to(jnp.arange(Skv)[None, :], (B, Skv))
    else:
        k_pos_all = kv_positions

    blk_q = _pick_chunk(Sq, block_q or FLASH_BLOCK_Q)
    blk_k = _pick_chunk(Skv, block_k or FLASH_BLOCK_K)
    nQ, nK = Sq // blk_q, Skv // blk_k

    # (nK, B, blk_k, ...) KV blocks as scan xs
    k_b = k.reshape(B, nK, blk_k, Hkv, hd).transpose(1, 0, 2, 3, 4)
    v_b = v.reshape(B, nK, blk_k, Hkv, hd).transpose(1, 0, 2, 3, 4)
    kp_b = k_pos_all.reshape(B, nK, blk_k).transpose(1, 0, 2)

    kv_len_col = None if kv_lens is None else kv_lens[:, None, None]

    def q_chunk(args):
        q_blk, qp_blk = args                     # (B, blk_q, Hkv, g, hd), (B, blk_q)

        def kv_step(carry, xs):
            m, l, acc = carry
            k_blk, v_blk, kp_blk = xs            # (B, blk_k, Hkv, hd), (B, blk_k)
            s = jnp.einsum(
                "bqhgd,bkhd->bqhgk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            )                                     # (B, blk_q, Hkv, g, blk_k)
            mask = jnp.ones((B, blk_q, blk_k), bool)
            if causal:
                mask &= kp_blk[:, None, :] <= qp_blk[:, :, None]
            if sliding_window:
                mask &= kp_blk[:, None, :] > (qp_blk[:, :, None] - sliding_window)
            if kv_len_col is not None:
                mask &= kp_blk[:, None, :] < kv_len_col
            maskh = mask[:, :, None, None, :]
            s = jnp.where(maskh, s, -jnp.inf)

            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # masked-out whole rows keep m == -inf; guard the exp
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            alpha = jnp.where(
                jnp.isfinite(m), jnp.exp(m - m_safe), 0.0
            )
            p = jnp.where(maskh, jnp.exp(s - m_safe[..., None]), 0.0)
            # row-sums consume the f32 p inside its producing fusion; only
            # the bf16 copy crosses the HBM boundary into the PV matmul
            # (halves the S^2 traffic vs an f32 p boundary)
            l = l * alpha + jnp.sum(p, axis=-1)
            p16 = p.astype(v_blk.dtype)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p16, v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l, acc), None

        m0 = jnp.full((B, blk_q, Hkv, g), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, blk_q, Hkv, g), jnp.float32)
        a0 = jnp.zeros((B, blk_q, Hkv, g, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (k_b, v_b, kp_b))
        l_safe = jnp.where(l == 0.0, 1.0, l)
        return acc / l_safe[..., None]

    if nQ == 1:
        out = q_chunk((qf, q_pos))
    else:
        q_c = qf.reshape(B, nQ, blk_q, Hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
        p_c = q_pos.reshape(B, nQ, blk_q).transpose(1, 0, 2)
        out = jax.lax.map(q_chunk, (q_c, p_c))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hkv, g, hd)

    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block params + apply
# ---------------------------------------------------------------------------


def init_attention(rng, cfg, d_model: Optional[int] = None, cross: bool = False):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 5)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads, hd), dt),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, hd), dt),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, hd), dt),
        "wo": dense_init(ks[3], (cfg.n_heads, hd, d), dt, scale=1.0 / math.sqrt(d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
    return p


def qkv_project(p, x, cfg, positions=None, rope: bool = True):
    """x: (B, S, D) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd) with optional RoPE."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    v = constrain(v, ("batch", "seq", "kv_heads", None))
    if rope and positions is not None:
        # re-pin after rope: the roped outputs are new values, and an
        # unpinned k lets GSPMD pull the prefill-cache layout into the
        # attention loop (per-block all-gathers)
        q = constrain(apply_rope(q, positions, cfg.rope_theta),
                      ("batch", "seq", "heads", None))
        k = constrain(apply_rope(k, positions, cfg.rope_theta),
                      ("batch", "seq", "kv_heads", None))
    return q, k, v


def attn_output(p, attn, cfg):
    out = jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    # row-parallel output: under sequence parallelism (act_seq -> model) the
    # partial sums reduce-scatter over S instead of all-reducing
    return constrain(out, ("batch", "act_seq", "embed"))


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------


def init_ffn(rng, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(rng, 3)
    dt = jnp.dtype(dtype)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dt),
        "w_up": dense_init(ks[1], (d_model, d_ff), dt),
        "w_down": dense_init(ks[2], (d_ff, d_model), dt),
    }


def ffn(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = constrain(h, ("batch", "seq", "mlp"))
    return constrain(h @ p["w_down"], ("batch", "act_seq", "embed"))


# ---------------------------------------------------------------------------
# Mixture of Experts (grouped GShard-style dispatch; capacity-bounded)
# ---------------------------------------------------------------------------

MOE_GROUP_SIZE = 4096  # tokens per capacity group (hillclimb knob)


def init_moe(rng, cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 4)
    return {
        "router": dense_init(ks[0], (d, m.n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_ff), dt),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_ff), dt),
        "w_down": dense_init(ks[3], (m.n_experts, m.d_ff, d), dt),
    }


def moe_capacity(tokens_per_group: int, n_experts: int, top_k: int, cf: float) -> int:
    return max(1, math.ceil(tokens_per_group * top_k / n_experts * cf))


def moe_ffn(p, x, cfg, group_size: int = 0):
    """x: (B, S, D) -> (B, S, D).  Router in f32; experts in compute dtype.

    Tokens are reshaped into capacity groups of ``group_size`` tokens; each
    expert serves ``C = ceil(group_tokens * top_k / E * capacity_factor)``
    slots per group (GShard).  Overflowing tokens are dropped (residual path
    keeps them intact), the standard capacity-factor semantics.
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    gsz = group_size or min(MOE_GROUP_SIZE, T)
    if T % gsz:
        gsz = math.gcd(T, gsz) if math.gcd(T, gsz) > 1 else T
    G = T // gsz
    xg = x.reshape(G, gsz, D)
    xg = constrain(xg, ("batch", None, "embed"))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                       # (G, t, E)
    top_w, top_idx = jax.lax.top_k(probs, m.top_k)                # (G, t, K)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)  # renormalize

    C = moe_capacity(gsz, m.n_experts, m.top_k, m.capacity_factor)

    dispatch = jnp.zeros((G, gsz, m.n_experts, C), dtype=x.dtype)
    combine = jnp.zeros((G, gsz, m.n_experts, C), dtype=jnp.float32)
    counts = jnp.zeros((G, m.n_experts), dtype=jnp.int32)
    for j in range(m.top_k):
        mask_j = jax.nn.one_hot(top_idx[:, :, j], m.n_experts, dtype=jnp.int32)  # (G,t,E)
        pos_j = counts[:, None, :] + jnp.cumsum(mask_j, axis=1) - mask_j         # (G,t,E)
        within = (pos_j < C) & (mask_j > 0)
        slot = jnp.sum(pos_j * mask_j, axis=-1)                                  # (G,t)
        slot_oh = jax.nn.one_hot(slot, C, dtype=x.dtype)                         # (G,t,C)
        d_j = within.astype(x.dtype)[..., None] * slot_oh[:, :, None, :]         # (G,t,E,C)
        dispatch = dispatch + d_j
        combine = combine + top_w[:, :, j, None, None].astype(jnp.float32) * d_j.astype(jnp.float32)
        counts = counts + jnp.sum(mask_j * within.astype(jnp.int32), axis=1)

    # (E, G, C, D): every expert serves G*C slots
    expert_in = jnp.einsum("gtec,gtd->egcd", dispatch, xg)
    expert_in = constrain(expert_in, ("experts", None, None, "embed"))
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("egcd,edf->egcf", expert_in, p["w_up"])
    h = constrain(h, ("experts", None, None, "moe_mlp"))
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["w_down"])
    expert_out = constrain(expert_out, ("experts", None, None, "embed"))

    out = jnp.einsum("gtec,egcd->gtd", combine.astype(expert_out.dtype), expert_out)
    return out.reshape(B, S, D)


def moe_ffn_scatter(p, x, cfg, group_size: int = 0):
    """Beyond-paper optimized MoE path: group-local sort/gather dispatch.

    vs the one-hot GShard einsums: no (G, t, E, C) dispatch/combine tensors
    (O(T*E*C) memory + FLOPs) -- tokens scatter directly into per-expert
    buffers.  Groups ride the batch sharding, so dispatch is LOCAL to each
    data shard (zero dispatch collectives under pjit); only the usual TP
    contribution of the expert matmuls communicates."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    gsz = group_size or min(MOE_GROUP_SIZE, T)
    if T % gsz:
        gsz = math.gcd(T, gsz) if math.gcd(T, gsz) > 1 else T
    G = T // gsz
    xg = x.reshape(G, gsz, D)
    xg = constrain(xg, ("batch", None, "embed"))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, m.top_k)                 # (G, t, K)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)

    C = moe_capacity(gsz, m.n_experts, m.top_k, m.capacity_factor)
    flat_e = top_idx.reshape(G, gsz * m.top_k)                     # (G, tK)
    eq = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)      # (G, tK, E)
    pos = jnp.cumsum(eq, axis=1) - eq
    slot_in_e = jnp.sum(pos * eq, axis=-1)                         # (G, tK)
    ok = slot_in_e < C
    dest = jnp.where(ok, flat_e * C + slot_in_e, m.n_experts * C)  # (G, tK)

    src = jnp.repeat(xg, m.top_k, axis=1)                          # (G, tK, D)

    def scatter_one(dest_g, src_g):
        buf = jnp.zeros((m.n_experts * C + 1, D), dtype=x.dtype)
        return buf.at[dest_g].set(src_g, mode="drop")

    buf = jax.vmap(scatter_one)(dest, src)                         # (G, EC+1, D)
    expert_in = buf[:, :-1].reshape(G, m.n_experts, C, D)
    expert_in = constrain(expert_in, ("batch", "experts", None, "embed"))

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    h = constrain(h, ("batch", "experts", None, "moe_mlp"))
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])      # (G, E, C, D)
    expert_out = constrain(expert_out, ("batch", "experts", None, "embed"))

    flat_out = expert_out.reshape(G, m.n_experts * C, D)
    safe = jnp.clip(dest, 0, m.n_experts * C - 1)
    gathered = jnp.take_along_axis(flat_out, safe[..., None], axis=1)
    gathered = jnp.where(ok[..., None], gathered, 0.0)             # (G, tK, D)
    w = top_w.reshape(G, gsz * m.top_k, 1).astype(gathered.dtype)
    out = jnp.sum((gathered * w).reshape(G, gsz, m.top_k, D), axis=2)
    return out.reshape(B, S, D)
