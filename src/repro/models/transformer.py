"""Decoder-only transformer LM (dense / MoE / SWA / VLM families).

Layers are stored stacked (leading layer dim) and executed with
``jax.lax.scan`` so that 88-layer configs lower to a single compact HLO loop.
Supports three entry points:

  * ``train_logits``  — full-sequence logits (used by the training step)
  * ``prefill``       — forward + KV-cache construction, last-position logits
  * ``decode``        — one token with a padded (or SWA ring) KV cache
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L


def _block_init(rng, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p: Dict[str, Any] = {
        "attn_norm": jnp.ones((cfg.d_model,), dt),
        "attn": L.init_attention(ks[0], cfg),
        "ffn_norm": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.moe is not None and cfg.moe.every == 1:
        p["moe"] = L.init_moe(ks[1], cfg)
        if cfg.moe.dense_residual:
            p["ffn"] = L.init_ffn(ks[2], cfg.d_model, cfg.d_ff, dt)
    else:
        p["ffn"] = L.init_ffn(ks[1], cfg.d_model, cfg.d_ff, dt)
    return p


def _block_ffn(p, x, cfg: ModelConfig):
    h = L.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    if "moe" in p:
        moe_fn = L.moe_ffn_scatter if cfg.moe_impl == "scatter" else L.moe_ffn
        out = moe_fn(p["moe"], h, cfg)
        if "ffn" in p:  # arctic dense residual (parallel branch)
            out = out + L.ffn(p["ffn"], h)
    else:
        out = L.ffn(p["ffn"], h)
    return x + out


def _block_fwd(p, x, positions, cfg: ModelConfig, collect_kv: bool):
    """Full-sequence causal block (train / prefill)."""
    h = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg, positions)
    attn = L.attention(
        q, k, v, q_offset=0, causal=True, sliding_window=cfg.sliding_window
    )
    # residual stream may be sequence-sharded (Megatron-SP style): norms and
    # residual adds then run on S/TP-sharded activations; GSPMD turns the
    # row-parallel matmuls' all-reduces into reduce-scatter + all-gather
    x = constrain(x + L.attn_output(p["attn"], attn, cfg),
                  ("batch", "act_seq", "embed"))
    x = constrain(_block_ffn(p, x, cfg), ("batch", "act_seq", "embed"))
    cache_axes = ("batch", "cache_seq", "cache_heads", "cache_hd")
    kv = (constrain(k, cache_axes), constrain(v, cache_axes)) if collect_kv else None
    return x, kv


def _block_decode(p, x, cache_k, cache_v, lens, cfg: ModelConfig, kv_positions=None):
    """Single-token block against a padded KV cache.

    cache_k/v: (B, S, Hkv, hd); lens: (B,) current lengths (write position for
    linear caches; for SWA ring caches the write slot is lens % W and
    ``kv_positions`` carries per-slot absolute positions).
    """
    B = x.shape[0]
    h = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q, k_new, v_new = L.qkv_project(p["attn"], h, cfg, lens[:, None])

    W = cache_k.shape[1]
    slot = lens % W if cfg.sliding_window else lens
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k_new[:, 0])
    cache_v = cache_v.at[bidx, slot].set(v_new[:, 0])

    if cfg.sliding_window:
        new_kv_positions = kv_positions.at[bidx, slot].set(lens)
        attn = L.attention(
            q, cache_k, cache_v,
            q_offset=lens, causal=True, sliding_window=cfg.sliding_window,
            kv_positions=new_kv_positions,
        )
    else:
        new_kv_positions = None
        attn = L.attention(q, cache_k, cache_v, q_offset=lens, kv_lens=lens + 1)
    x = x + L.attn_output(p["attn"], attn, cfg)
    x = _block_ffn(p, x, cfg)
    return x, cache_k, cache_v, new_kv_positions


class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- params ------------------------------------------------------------
    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        k_emb, k_layers, k_head = jax.random.split(rng, 3)
        layer_rngs = jax.random.split(k_layers, cfg.n_layers)
        stacked = jax.vmap(lambda r: _block_init(r, cfg))(layer_rngs)
        params = {
            "embed": L.dense_init(k_emb, (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
            "layers": stacked,
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(
                k_head, (cfg.d_model, cfg.vocab_size), dt, scale=1.0 / math.sqrt(cfg.d_model)
            )
        return params

    # -- shared ------------------------------------------------------------
    def _embed_inputs(self, params, batch: Dict[str, Any]):
        cfg = self.cfg
        tok_emb = params["embed"][batch["tokens"]]  # (B, St, D) gather
        if cfg.n_patch_tokens and "patch_embeds" in batch:
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(tok_emb.dtype), tok_emb], axis=1
            )
        else:
            x = tok_emb
        return constrain(x, ("batch", "seq", "embed"))

    def _unembed(self, params, x):
        if "lm_head" in params:
            logits = jnp.einsum("...d,dv->...v", x, params["lm_head"])
        else:
            logits = jnp.einsum("...d,vd->...v", x, params["embed"])
        return logits

    def _run_layers(self, params, x, positions, collect_kv: bool, remat: bool):
        cfg = self.cfg

        def body(carry, lp):
            y, kv = _block_fwd(lp, carry, positions, cfg, collect_kv)
            return y, kv

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, kvs = jax.lax.scan(body, x, params["layers"])
        return x, kvs

    def unembed_weight(self, params):
        if "lm_head" in params:
            return params["lm_head"], "dv"
        return params["embed"], "vd"

    # -- entry points --------------------------------------------------------
    def train_hidden(self, params, batch: Dict[str, Any], remat: bool = True):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x, _ = self._run_layers(params, x, positions, collect_kv=False, remat=remat)
        return L.rms_norm(x, params["final_norm"], cfg.norm_eps)

    def train_logits(self, params, batch: Dict[str, Any], remat: bool = True):
        logits = self._unembed(params, self.train_hidden(params, batch, remat))
        return constrain(logits, ("batch", "seq", "vocab"))

    def prefill(self, params, batch: Dict[str, Any]):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x, (ks, vs) = self._run_layers(params, x, positions, collect_kv=True, remat=False)
        x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = self._unembed(params, x)[:, 0]

        if cfg.sliding_window:
            # Always return a W-sized ring cache so decode wraps correctly.
            W = cfg.sliding_window
            nL = ks.shape[0]
            if S >= W:
                pos = jnp.arange(S - W, S)
                slots = pos % W
                ks_r = jnp.zeros_like(ks[:, :, :W]).at[:, :, slots].set(ks[:, :, S - W:])
                vs_r = jnp.zeros_like(vs[:, :, :W]).at[:, :, slots].set(vs[:, :, S - W:])
                kv_pos = jnp.zeros((B, W), jnp.int32).at[:, slots].set(pos[None, :])
            else:
                pad = [(0, 0), (0, 0), (0, W - S), (0, 0), (0, 0)]
                ks_r = jnp.pad(ks, pad)
                vs_r = jnp.pad(vs, pad)
                kv_pos = jnp.full((B, W), L.INVALID_POS, jnp.int32).at[:, :S].set(
                    jnp.arange(S)[None, :]
                )
            cache = {
                "k": ks_r,
                "v": vs_r,
                "kv_pos": jnp.broadcast_to(kv_pos[None], (nL, B, W)),
            }
        else:
            cache = {"k": ks, "v": vs}  # (L, B, S, Hkv, hd)
        return logits, cache

    def decode(self, params, tokens, cache, lens):
        """tokens: (B, 1); cache k/v: (L, B, S, Hkv, hd); lens: (B,)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        x = constrain(x, ("batch", None, "embed"))

        has_pos = "kv_pos" in cache

        def body(carry, xs):
            if has_pos:
                lp, ck, cv, kp = xs
            else:
                lp, ck, cv = xs
                kp = None
            y, ck, cv, kp = _block_decode(lp, carry, ck, cv, lens, cfg, kv_positions=kp)
            return y, ((ck, cv, kp) if has_pos else (ck, cv))

        xs = (params["layers"], cache["k"], cache["v"])
        if has_pos:
            xs = xs + (cache["kv_pos"],)
        x, new = jax.lax.scan(body, x, xs)
        new_cache = {"k": new[0], "v": new[1]}
        if has_pos:
            new_cache["kv_pos"] = new[2]
        x = L.rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
        logits = self._unembed(params, x)
        return constrain(logits, ("batch", "vocab")), new_cache

    def chunked_step(self, params, tokens, cache, lens, chunk_lens,
                     *, use_pallas: bool = False):
        """One chunked-prefill engine round (Sarathi semantics, §3.1).

        The mixed batch is slot-aligned: every sequence slot advances by its
        ``chunk_lens[b]`` tokens this round — decode slots advance by 1 (their
        freshly sampled token), prefill slots by their scheduled chunk,
        inactive slots by 0.  tokens: (B, C) right-padded; cache k/v:
        (L, B, S+1, Hkv, hd) — the +1 row is a write sink for padding;
        lens: (B,) tokens already in cache; returns (logits_at_chunk_end,
        new_cache).

        Attention is the chunked-prefill kernel's exact computation: the
        chunk's queries attend to (prefix ‖ chunk) with a causal offset —
        ``use_pallas=True`` runs kernels/chunked_prefill_attention (interpret
        mode on CPU, Mosaic on TPU); False uses its jnp oracle.
        """
        from repro.kernels import ops as kops

        cfg = self.cfg
        assert not cfg.sliding_window, "engine demo path supports linear caches"
        B, C = tokens.shape
        S_pad = cache["k"].shape[2]          # S + 1 (padding sink row)
        positions = lens[:, None] + jnp.arange(C)[None, :]
        write_mask = jnp.arange(C)[None, :] < chunk_lens[:, None]
        # padding positions scatter into the sink row S_pad-1
        write_pos = jnp.where(write_mask, positions, S_pad - 1)
        kv_lens = lens + chunk_lens
        bidx = jnp.arange(B)

        x = params["embed"][tokens]
        x = constrain(x, ("batch", "seq", "embed"))

        def body(carry, xs):
            lp, ck, cv = xs
            h = L.rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
            q, k_new, v_new = L.qkv_project(lp["attn"], h, cfg, positions)
            ck = ck.at[bidx[:, None], write_pos].set(k_new)
            cv = cv.at[bidx[:, None], write_pos].set(v_new)
            attn = kops.prefill_chunk_attention(
                q, ck[:, :-1], cv[:, :-1], kv_lens, lens,
                use_pallas=use_pallas,
            )
            y = carry + L.attn_output(lp["attn"], attn, cfg)
            y = _block_ffn(lp, y, cfg)
            return y, (ck, cv)

        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        # logits at each slot's last chunk position (chunk_len-1; slot 0 for idle)
        last = jnp.maximum(chunk_lens - 1, 0)
        x_last = x[bidx, last]                       # (B, D)
        logits = self._unembed(params, x_last)
        return constrain(logits, ("batch", "vocab")), {"k": nk, "v": nv}

    def chunked_step_paged(self, params, tokens, kv_pages, lens, chunk_lens,
                           block_tables, *, use_pallas: bool = False,
                           pages_per_tile: int = 1,
                           kv_layout: str = "split",
                           buffering_depth: int = 1):
        """``chunked_step`` against a *paged* KV cache (vLLM layout).

        Same Sarathi round semantics and bit-level math as the dense path, but
        K/V live in a shared physical page pool ``(L, n_pages, page_size,
        Hkv, hd)`` addressed through per-slot block tables ``(B, max_pages)``
        instead of a ``(L, B, S+1, ...)`` slot-dense tensor.  New K/V for
        position ``p`` of slot ``b`` scatters to flat physical row
        ``block_tables[b, p // ps] * ps + p % ps``; padding positions scatter
        into the last physical page (the sink, which block tables also use as
        their pad value) and are never read back (``kv_lens`` masks them).

        ``kv_layout="fused"`` stores the pool head-interleaved
        (``kv_pages["kv"]: (L, n_phys, ps, 2*Hkv, hd)``, heads
        ``[K0,V0,K1,V1,...]``): the round's new K/V interleave into ONE
        scatter per layer and the attention kernel fetches each page's K+V
        with one DMA.  ``buffering_depth`` gathers run ahead of the kernels'
        dots (1 = synchronous).

        Attention is the paged chunked-prefill kernel (or the paged flash-
        decode kernel when the bucket is a pure single-token round) with a
        pure-jnp gather oracle behind the same ``use_pallas`` flag.
        """
        from repro.kernels import ops as kops

        cfg = self.cfg
        assert not cfg.sliding_window, "engine demo path supports linear caches"
        fused = kv_layout == "fused"
        B, C = tokens.shape
        pool = kv_pages["kv"] if fused else kv_pages["k"]
        n_phys, ps = pool.shape[1], pool.shape[2]
        positions = lens[:, None] + jnp.arange(C)[None, :]
        write_mask = jnp.arange(C)[None, :] < chunk_lens[:, None]
        bidx = jnp.arange(B)
        # logical position -> physical flat row via the block table
        page_of = block_tables[bidx[:, None], positions // ps]     # (B, C)
        flat_pos = page_of * ps + positions % ps
        # padding positions scatter into the sink page (last physical page)
        write_pos = jnp.where(write_mask, flat_pos, (n_phys - 1) * ps)
        kv_lens = lens + chunk_lens

        x = params["embed"][tokens]
        x = constrain(x, ("batch", "seq", "embed"))

        def scatter(pages, new):
            return pages.reshape(n_phys * ps, *pages.shape[2:]).at[
                write_pos].set(new).reshape(pages.shape)

        def body(carry, xs):
            h = L.rms_norm(carry, xs[0]["attn_norm"], cfg.norm_eps)
            q, k_new, v_new = L.qkv_project(xs[0]["attn"], h, cfg, positions)
            # masked lanes land in the SHARED sink page: write zeros, never
            # lane values — idle rows carry NaN (all-masked softmax, same as
            # the dense path) and a NaN parked in shared storage would
            # poison other rows' masked-position 0*V products downstream
            k_new = jnp.where(write_mask[:, :, None, None], k_new, 0)
            v_new = jnp.where(write_mask[:, :, None, None], v_new, 0)
            if fused:
                lp, ckv = xs                   # (n_phys, ps, 2*Hkv, hd)
                Hkv, hd = k_new.shape[2], k_new.shape[3]
                # interleave onto the head axis: ONE scatter writes K and V
                kv_new = jnp.stack([k_new, v_new], axis=3).reshape(
                    B, C, 2 * Hkv, hd)
                ckv = scatter(ckv, kv_new)
                if C == 1:
                    attn = kops.paged_flash_decode_attention_fused(
                        q[:, 0], ckv, block_tables, kv_lens,
                        use_pallas=use_pallas, pages_per_tile=pages_per_tile,
                        buffering_depth=buffering_depth,
                    )[:, None]
                else:
                    attn = kops.paged_prefill_chunk_attention_fused(
                        q, ckv, block_tables, kv_lens, lens,
                        use_pallas=use_pallas, pages_per_tile=pages_per_tile,
                        buffering_depth=buffering_depth,
                    )
                new_pages = (ckv,)
            else:
                lp, ck, cv = xs                # (n_phys, ps, Hkv, hd)
                ck = scatter(ck, k_new)
                cv = scatter(cv, v_new)
                if C == 1:
                    attn = kops.paged_flash_decode_attention(
                        q[:, 0], ck, cv, block_tables, kv_lens,
                        use_pallas=use_pallas, pages_per_tile=pages_per_tile,
                        buffering_depth=buffering_depth,
                    )[:, None]
                else:
                    attn = kops.paged_prefill_chunk_attention(
                        q, ck, cv, block_tables, kv_lens, lens,
                        use_pallas=use_pallas, pages_per_tile=pages_per_tile,
                        buffering_depth=buffering_depth,
                    )
                new_pages = (ck, cv)
            y = carry + L.attn_output(lp["attn"], attn, cfg)
            y = _block_ffn(lp, y, cfg)
            return y, new_pages

        if fused:
            x, (nkv,) = jax.lax.scan(
                body, x, (params["layers"], kv_pages["kv"])
            )
            new_cache = {"kv": nkv}
        else:
            x, (nk, nv) = jax.lax.scan(
                body, x, (params["layers"], kv_pages["k"], kv_pages["v"])
            )
            new_cache = {"k": nk, "v": nv}
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        last = jnp.maximum(chunk_lens - 1, 0)
        x_last = x[bidx, last]                       # (B, D)
        logits = self._unembed(params, x_last)
        return constrain(logits, ("batch", "vocab")), new_cache

    # -- cache/spec helpers ---------------------------------------------------
    def cache_struct(self, batch: int, seq_len: int):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        S = cfg.sliding_window if cfg.sliding_window else seq_len
        hd = cfg.resolved_head_dim
        shape = (cfg.n_layers, batch, S, cfg.n_kv_heads, hd)
        c = {
            "k": jax.ShapeDtypeStruct(shape, dt),
            "v": jax.ShapeDtypeStruct(shape, dt),
        }
        if cfg.sliding_window:
            c["kv_pos"] = jax.ShapeDtypeStruct((cfg.n_layers, batch, S), jnp.int32)
        return c
