"""arctic-480b — [moe] 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual.

[hf:Snowflake/snowflake-arctic-base; hf]
Dense-MoE hybrid: every layer has a dense residual FFN in parallel with the
128-expert top-2 MoE FFN (d_ff=4864 for both, matching the HF config's
intermediate size for the MoE branch).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff=4864, dense_residual=True, every=1),
    rope_theta=10_000.0,
    moe_impl="scatter",
    sharding="fsdp_tp",
    subquadratic=False,
    notes="128 experts top-2 + dense residual; EP over model axis",
)
