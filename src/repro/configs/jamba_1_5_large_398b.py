"""jamba-1.5-large-398b — [hybrid] 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2; Mamba+attention 1:7 interleave.

[arXiv:2403.19887; hf]
Layer l is attention iff l % 8 == 0 (1 attn : 7 mamba); FFN is MoE on odd layers
(every=2) per the Jamba block design.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    attn_every=8,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=24_576, every=2),
    ssm=SSMConfig(kind="mamba", d_state=16, conv_width=4, expand=2),
    sharding="fsdp_tp",
    subquadratic=True,   # mamba-dominated -> long_500k runs
    moe_impl="scatter",
    notes="398B hybrid MoE; KV cache only on 9 of 72 layers",
)
