"""mixtral-8x7b — [moe] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8e top-2, sliding-window attention.

[arXiv:2401.04088; hf]
SWA window 4096 -> sub-quadratic: long_500k decode keeps an O(W) ring-buffer KV
cache, so the shape runs.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=14_336, every=1),
    rope_theta=1_000_000.0,
    sharding="fsdp_tp",
    subquadratic=True,   # SWA => O(W) decode cache
    moe_impl="scatter",  # group-local dispatch (see EXPERIMENTS.md §Perf)
    notes="8 experts top-2; SWA window 4096",
)
