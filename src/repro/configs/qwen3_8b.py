"""qwen3-8b — the paper's own evaluation model (§4.1).

36L d_model=4096 32H (GQA kv=8, head_dim 128) d_ff=12288 vocab=151936.
[hf:Qwen/Qwen3-8B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12_288,
    vocab_size=151_936,
    head_dim=128,
    rope_theta=1_000_000.0,
    sharding="tp",
    subquadratic=False,
    notes="paper's evaluation model",
)
