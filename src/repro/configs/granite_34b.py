"""granite-34b — [dense] 88L d_model=6144 48H (GQA kv=1 == MQA) d_ff=24576 vocab=49152.

[arXiv:2405.04324; hf] llama-arch code model. kv=1 < TP degree, so KV heads are
replicated under tensor parallelism (see distributed/sharding.py).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24_576,
    vocab_size=49_152,
    rope_theta=10_000.0,
    sharding="fsdp_tp",
    subquadratic=False,
    notes="MQA (kv=1); 34B params; 2D weight sharding for serving",
)
