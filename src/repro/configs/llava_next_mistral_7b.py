"""llava-next-mistral-7b — [vlm] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
Mistral-7B backbone. The anyres vision tower + projector are a STUB per
assignment: input_specs() provides precomputed patch embeddings
(batch, n_patch_tokens, d_model) that are prepended to the text embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    n_patch_tokens=576,   # one 24x24 anyres base grid (stubbed embeddings)
    rope_theta=1_000_000.0,
    sharding="tp",
    subquadratic=False,
    notes="vision frontend stubbed; backbone == mistral-7b",
)
