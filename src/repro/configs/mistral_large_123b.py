"""mistral-large-123b — [dense] 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.

[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
123B params: TP=16 alone leaves ~15.4 GB of weights per chip (v5e has 16 GB), so
serving uses 2D weight sharding (fsdp_tp) with per-layer gather.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12_288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28_672,
    vocab_size=32_768,
    rope_theta=1_000_000.0,
    sharding="fsdp_tp",
    subquadratic=False,
    notes="123B dense; 2D weight sharding",
)
