"""xlstm-1.3b — [ssm] 48L d_model=2048 4H d_ff=0 vocab=50304; sLSTM + mLSTM blocks.

[arXiv:2405.04517; unverified]
xLSTM[7:1]: one sLSTM block per 8 layers, rest mLSTM (matrix-memory, chunkwise-
parallel prefill). d_ff=0: blocks carry their own internal up-projection
(mLSTM 2x, sLSTM 4/3x gated MLP) per the paper. Pure recurrence -> O(1) decode
state, long_500k runs.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    ssm=SSMConfig(kind="xlstm", slstm_every=8, chunk_size=256),
    sharding="tp",
    subquadratic=True,
    notes="sLSTM:mLSTM 1:7; head_dim 512; recurrent state only (no KV cache)",
)
