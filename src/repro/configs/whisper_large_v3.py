"""whisper-large-v3 — [audio] 32L d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866.

[arXiv:2212.04356; unverified]
Encoder-decoder. The conv audio frontend is a STUB per assignment: input_specs()
provides precomputed frame embeddings (batch, 1500, d_model); the 32-layer
bidirectional encoder and the 32-layer decoder (self-attn + cross-attn) are real.
Full attention -> long_500k skipped.
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    encoder=EncoderConfig(n_layers=32, seq_len=1500),
    rope_theta=10_000.0,
    sharding="tp",
    subquadratic=False,
    notes="enc-dec; conv frontend stubbed (precomputed frame embeddings)",
)
