"""Architecture config registry.

``get_config(arch_id)`` resolves the assigned architecture ids (and the paper's
own evaluation model) to :class:`repro.configs.base.ModelConfig`.
``tiny_config(arch_id)`` produces a reduced same-family config for CPU smoke
tests (small layers/width, few experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (
    SHAPES,
    SHAPES_BY_NAME,
    EncoderConfig,
    ModelConfig,
    MoEConfig,
    ShapeSpec,
    SSMConfig,
    applicable_shapes,
)

from repro.configs.llama3_2_1b import CONFIG as _llama
from repro.configs.granite_34b import CONFIG as _granite
from repro.configs.qwen1_5_0_5b import CONFIG as _qwen05
from repro.configs.mistral_large_123b import CONFIG as _mistral_large
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.qwen3_8b import CONFIG as _qwen3

REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _llama,
        _granite,
        _qwen05,
        _mistral_large,
        _jamba,
        _whisper,
        _arctic,
        _mixtral,
        _llava,
        _xlstm,
        _qwen3,
    )
}

ASSIGNED_ARCHS: List[str] = [
    "llama3.2-1b",
    "granite-34b",
    "qwen1.5-0.5b",
    "mistral-large-123b",
    "jamba-1.5-large-398b",
    "whisper-large-v3",
    "arctic-480b",
    "mixtral-8x7b",
    "llava-next-mistral-7b",
    "xlstm-1.3b",
]


def get_config(arch_id: str) -> ModelConfig:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(REGISTRY)}"
        ) from None


def tiny_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(arch_id)
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.attn_every == 0 else cfg.attn_every),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        d_ff=128 if cfg.d_ff else 0,
        head_dim=16,
        vocab_size=512,
        max_seq_len=512,
        sharding="tp",
        name=cfg.name + "-tiny",
    )
    if cfg.attn_every:
        kw["n_layers"] = cfg.attn_every  # one full interleave group
    if cfg.moe is not None:
        # capacity_factor high enough that no token drops: keeps tiny-config
        # consistency tests exact (capacity dropping is workload-dependent)
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff=128, capacity_factor=8.0
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=8, chunk_size=16)
        if cfg.ssm.kind == "xlstm":
            kw["n_layers"] = cfg.ssm.slstm_every
            kw["head_dim"] = 16
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(n_layers=2, seq_len=16)
        kw["n_layers"] = 2
    if cfg.n_patch_tokens:
        kw["n_patch_tokens"] = 8
    if cfg.sliding_window:
        kw["sliding_window"] = 32
    return cfg.with_(**kw)


__all__ = [
    "REGISTRY",
    "ASSIGNED_ARCHS",
    "get_config",
    "tiny_config",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "EncoderConfig",
    "ShapeSpec",
    "SHAPES",
    "SHAPES_BY_NAME",
    "applicable_shapes",
]
