"""Unified model / shape configuration for the FairServe-JAX model zoo.

Every assigned architecture is expressed as a :class:`ModelConfig`.  The model
factory (``repro.models.model``) consumes these to build init/apply/prefill/
decode/train step functions; ``repro.launch.dryrun`` consumes the paired
:class:`ShapeSpec` set to lower every (arch x shape) cell.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                 # per-expert hidden dim
    capacity_factor: float = 1.25
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    every: int = 1                 # MoE FFN every `every` layers (1 = all layers)


@dataclass(frozen=True)
class SSMConfig:
    kind: str                 # "mamba" | "xlstm"
    d_state: int = 16         # mamba state dim
    conv_width: int = 4
    expand: int = 2           # d_inner = expand * d_model
    dt_rank: int = 0          # 0 -> d_model // 16
    # xlstm
    slstm_every: int = 8      # 1 sLSTM per `slstm_every` layers (rest mLSTM)
    chunk_size: int = 256     # chunkwise-parallel mLSTM prefill chunk


@dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    seq_len: int              # e.g. whisper: 1500 audio frames (post-conv, stubbed)
    d_model: int = 0          # 0 -> same as decoder d_model


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                 # dense FFN hidden dim (0 for pure-SSM blocks)
    vocab_size: int

    head_dim: int = 0         # 0 -> d_model // n_heads
    qkv_bias: bool = False    # qwen1.5
    sliding_window: int = 0   # mixtral SWA; 0 = full attention
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None

    # hybrid (jamba): one attention layer per `attn_every` layers, rest SSM
    attn_every: int = 0

    # vlm: number of (stubbed) image-patch embedding tokens prepended to text
    n_patch_tokens: int = 0

    # distribution
    sharding: str = "tp"      # "tp" | "fsdp_tp" (big models: 2D weight sharding)
    scan_layers: bool = True
    # MoE dispatch: "onehot" (GShard dispatch/combine einsums) or "scatter"
    # (sort/gather; no O(T*E*C) dispatch tensors) — a §Perf hillclimb lever
    moe_impl: str = "onehot"

    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # which shape families apply (per-spec skips)
    subquadratic: bool = False   # True -> long_500k runs (SSM/hybrid/SWA)
    has_decode: bool = True      # encoder-only archs would set False

    max_seq_len: int = 131_072

    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        hd = self.resolved_head_dim
        d = self.d_model
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + self.n_heads * hd * d
        dense_ffn = 3 * d * self.d_ff if self.d_ff else 0
        n = 0
        for li in range(self.n_layers):
            is_attn = (self.attn_every == 0) or (li % self.attn_every == 0)
            if self.ssm is not None and not is_attn:
                n += self._ssm_params()
            elif self.ssm is not None and self.family == "ssm":
                n += self._ssm_params()
            else:
                n += attn
            if self.moe is not None and (li % self.moe.every == (self.moe.every - 1)):
                n += self.moe.n_experts * 3 * d * self.moe.d_ff + d * self.moe.n_experts
                if self.moe.dense_residual:
                    n += dense_ffn
            elif self.d_ff:
                n += dense_ffn
            n += 2 * d  # norms
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.encoder is not None:
            ed = self.encoder.d_model or d
            ehd = ed // self.n_heads
            enc_attn = 4 * ed * ehd * self.n_heads
            n += self.encoder.n_layers * (enc_attn + 3 * ed * self.d_ff + 2 * ed)
            n += self.n_layers * attn  # decoder cross-attention
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        per_layer_moe = self.moe.n_experts * 3 * self.d_model * self.moe.d_ff
        active_moe = self.moe.top_k * 3 * self.d_model * self.moe.d_ff
        n_moe_layers = sum(
            1 for li in range(self.n_layers) if li % self.moe.every == (self.moe.every - 1)
        )
        return full - n_moe_layers * (per_layer_moe - active_moe)

    def _ssm_params(self) -> int:
        d = self.d_model
        if self.ssm is None:
            return 0
        if self.ssm.kind == "mamba":
            d_in = self.ssm.expand * d
            dt_rank = self.ssm.dt_rank or d // 16
            return (
                2 * d * d_in                       # in_proj
                + d_in * self.ssm.conv_width       # conv
                + d_in * (dt_rank + 2 * self.ssm.d_state)  # x_proj
                + dt_rank * d_in                   # dt_proj
                + d_in * self.ssm.d_state          # A_log
                + d_in                             # D
                + d_in * d                         # out_proj
            )
        # xlstm mLSTM block (matches models/xlstm.py init_mlstm):
        # wq/wk/wv (d, H, hd) + wog (d, d_in) + down (d_in, d) + gates
        H = self.n_heads
        hd = self.resolved_head_dim
        d_in = H * hd
        return 3 * d * d_in + 2 * d * H + d * d_in + d_in * d

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", "train", 4096, 256),
    ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    ShapeSpec("decode_32k", "decode", 32_768, 128),
    ShapeSpec("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeSpec, ...]:
    """Per-spec skips: long_500k only for sub-quadratic archs; decode shapes
    only for archs with a decode step."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.subquadratic:
            continue
        if s.kind == "decode" and not cfg.has_decode:
            continue
        out.append(s)
    return tuple(out)
