"""Multi-tenant fairness subsystem (layered above the paper's scheduler).

Components:
  * ``tenants``   — TenantSpec / TenantRegistry / FairnessConfig
  * ``vtc``       — weighted Virtual Token Counter (per-tenant service)
  * ``fair_queue``— two-level prefill queue (inter-tenant VTC, intra-tenant
                    FCFS/SJF/Aging)
  * ``admission`` — token-bucket admission with deprioritization penalties

``FairnessState`` wires the four together for one scheduler instance; it is
constructed by ``ChunkedPrefillScheduler`` when ``SchedulerConfig.fairness``
is set and is a no-op import otherwise.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Set

from repro.core.policies import PrefillQueue
from repro.core.request import Request, RequestState
from repro.tenancy.admission import AdmissionController, AdmissionDecision, TokenBucket
from repro.tenancy.fair_queue import FairPrefillQueue
from repro.tenancy.tenants import (
    DEFAULT_TENANT, FairnessConfig, TenantRegistry, TenantSpec,
)
from repro.tenancy.vtc import TenantService, VirtualTokenCounter


class FairnessState:
    """Per-scheduler composition of registry + VTC + admission + fair queue.

    The scheduler calls exactly three hooks, all guarded by
    ``cfg.fairness is not None``:
      * ``admit(req)``        at submit — token-bucket assessment
      * ``on_round(now)``     at schedule — advance the penalty clock
      * ``on_batch_done(b)``  post-execution — charge the VTC, retire
                              completed prefills, track decoding tenants
    """

    def __init__(
        self,
        cfg: FairnessConfig,
        policy_factory: Callable[[], PrefillQueue],
        *,
        vtc: Optional[VirtualTokenCounter] = None,
    ):
        self.cfg = cfg
        self.registry = TenantRegistry(cfg.tenants, auto_register=cfg.auto_register)
        # an injected counter is SHARED across schedulers (multi-replica
        # routers): every replica charges and reads the same per-tenant
        # virtual service, so a heavy tenant cannot launder load by fanning
        # requests across replicas — each replica's fair queue sees the
        # tenant's aggregate service, not its local slice
        self.vtc = vtc if vtc is not None else VirtualTokenCounter(
            self.registry,
            prefill_weight=cfg.prefill_charge_weight,
            decode_weight=cfg.decode_charge_weight,
        )
        self.admission: Optional[AdmissionController] = (
            AdmissionController(
                self.registry,
                policy=cfg.admission_policy,
                penalty_window_s=cfg.penalty_window_s,
            )
            if cfg.admission
            else None
        )
        self._decoding: Dict[str, Set[int]] = {}   # tenant -> decoding req_ids
        self.queue = FairPrefillQueue(
            policy_factory,
            self.vtc,
            admission=self.admission,
            extra_active_fn=self._decoding_tenants,
        )
        self.rejected: List[Request] = []
        self.shed: List[Request] = []          # SLO-shed at admission
        self.slo = None                        # SLOTracker (attach_slo)
        # first-token bonus charges issued by on_batch_done (the +1 decode
        # charged when a chunk completes a prefill, per Sarathi semantics);
        # the chaos suite's charge identity needs this ledger NET of refunds:
        #   charged == Σ scheduled tokens + first_token_charges
        self.first_token_charges = 0

    def _decoding_tenants(self) -> List[str]:
        return [t for t, ids in self._decoding.items() if ids]

    def attach_slo(self, tracker) -> None:
        """Wire an ``SLOTracker`` (built by the scheduler from
        ``SchedulerConfig.slo``) into the fairness subsystem: the admission
        controller gains the feasibility shed gate and the fair queue gains
        deadline urgency.  Each hook is gated on its feature flag so an
        all-flags-off tracker leaves behavior bit-identical."""
        self.slo = tracker
        if self.admission is not None and tracker.cfg.shed:
            self.admission.slo_gate = tracker.feasible
        if tracker.cfg.queue_urgency:
            self.queue.urgency_fn = tracker.urgent

    # -- scheduler hooks -------------------------------------------------------
    def admit(self, req: Request) -> AdmissionDecision:
        """Token-bucket assessment.  Returns the full decision: the scheduler
        routes ``delayed`` requests into the fair queue's holding pen and
        drops rejected ones."""
        if self.admission is None:
            return AdmissionDecision(tenant=req.tenant, admitted=True,
                                     penalized=False)
        decision = self.admission.assess(req)
        if not decision.admitted:
            (self.shed if decision.shed else self.rejected).append(req)
        return decision

    def on_preempt(self, req: Request) -> None:
        """A decoding request was evicted under KV pressure: it re-enters the
        prefill queue, so it must stop counting as decode-active (the queue
        re-``add`` restored its prefill ownership already)."""
        ids = self._decoding.get(req.tenant)
        if ids is not None:
            ids.discard(req.req_id)

    def on_resume(self, req: Request) -> None:
        """A swapped-out victim was restored straight into the decode set —
        it will never complete a prefill chunk again, so retire its queue
        ownership here (the path ``on_batch_done`` takes for ordinary
        prefill completions) and count it decode-active.  Its restore charges
        the VTC nothing: swap-out preemption must not tax the victim
        tenant's service accounting (FairBatching's requirement) the way a
        recompute's re-prefill tokens would."""
        self.queue.retire(req)
        self._decoding.setdefault(req.tenant, set()).add(req.req_id)

    def forget(self, req: Request) -> None:
        """The request left this scheduler outside the normal finish path — a
        value-dependent stop applied at drain, or a cross-replica handoff
        export.  Drop every piece of activity bookkeeping it holds here:
        queue ownership (the tenant stops counting as prefill-active) and
        decode-active membership.  Service already charged stays charged —
        tokens were really executed."""
        self.queue.retire(req)
        ids = self._decoding.get(req.tenant)
        if ids is not None:
            ids.discard(req.req_id)

    def refund_token(self, req: Request, *, first_token: bool = False) -> None:
        """Refund the charge of ONE rolled-back undrained token (crash or
        numerics quarantine discarded it before it became host-visible).  A
        token charged as the first-token bonus also decrements that ledger so
        the chaos suite's charge identity keeps balancing."""
        self.vtc.refund(req.tenant, decode_tokens=1)
        if first_token:
            self.first_token_charges -= 1

    def on_round(self, now: float) -> None:
        self.queue.set_now(now)

    def on_batch_done(self, batch) -> None:
        """Charge executed tokens and maintain activity bookkeeping.

        Called AFTER the scheduler applied chunk/token deliveries, so request
        states reflect the post-round world.
        """
        prefill: Dict[str, int] = {}
        decode: Dict[str, int] = {}
        for req, c in batch.prefill_chunks:
            prefill[req.tenant] = prefill.get(req.tenant, 0) + int(c)
            if req.state in (RequestState.DECODING, RequestState.FINISHED):
                # the round that completes a prefill also delivers the first
                # output token (Sarathi semantics) — charge it as decode so
                # per-tenant service matches tokens delivered
                decode[req.tenant] = decode.get(req.tenant, 0) + 1
                self.first_token_charges += 1
        for req in batch.decode_reqs:
            decode[req.tenant] = decode.get(req.tenant, 0) + 1
        for t in set(prefill) | set(decode):
            self.vtc.charge(t, prefill.get(t, 0), decode.get(t, 0))

        for req, _c in batch.prefill_chunks:
            if req.state in (RequestState.DECODING, RequestState.FINISHED):
                self.queue.retire(req)
            if req.state == RequestState.DECODING:
                self._decoding.setdefault(req.tenant, set()).add(req.req_id)
        for req in batch.decode_reqs:
            if req.state == RequestState.FINISHED:
                ids = self._decoding.get(req.tenant)
                if ids is not None:
                    ids.discard(req.req_id)

    # -- views ----------------------------------------------------------------
    def service_by_tenant(self) -> Dict[str, int]:
        return {t: self.vtc.actual_tokens(t) for t in self.vtc.tenants()}

    def virtual_by_tenant(self) -> Dict[str, float]:
        return {t: self.vtc.virtual_service(t) for t in self.vtc.tenants()}


def make_shared_vtc(cfg: FairnessConfig) -> VirtualTokenCounter:
    """One VirtualTokenCounter for a whole replica fleet: pass it as every
    scheduler's ``shared_vtc`` so per-tenant service aggregates across
    replicas (anti-laundering — see ``FairnessState``)."""
    registry = TenantRegistry(cfg.tenants, auto_register=cfg.auto_register)
    return VirtualTokenCounter(
        registry,
        prefill_weight=cfg.prefill_charge_weight,
        decode_weight=cfg.decode_charge_weight,
    )


__all__ = [
    "AdmissionController",
    "AdmissionDecision",
    "DEFAULT_TENANT",
    "FairPrefillQueue",
    "FairnessConfig",
    "FairnessState",
    "TenantRegistry",
    "TenantService",
    "TenantSpec",
    "TokenBucket",
    "VirtualTokenCounter",
    "make_shared_vtc",
]
