"""Two-level fair prefill queue: inter-tenant weighted fair sharing over
intra-tenant paper policies.

Level 1 (inter-tenant): among tenants with queued prefill work, pop from the
tenant with the LOWEST virtual service (``VirtualTokenCounter``) — weighted
max-min fairness across tenants.  Tenants inside an admission penalty window
are deprioritized: they are only served when no unpenalized tenant has work
(still starvation-free, since penalties expire).

Level 2 (intra-tenant): each tenant owns a private ``PrefillQueue`` built by
the configured policy factory (FCFS / SJF / Aging), so the paper's
request-level aging still orders requests WITHIN a tenant.

The class mirrors the ``PrefillQueue`` interface (add / pop / update /
remove / peek / len / contains / requests / drain_sorted) so the scheduler
is oblivious to which queue it holds.

Activity bookkeeping: a request is "owned" by the queue from first ``add``
until ``retire`` (prefill complete) or ``remove``; a tenant is active while
it owns requests.  The VTC lift fires only when a genuinely idle tenant
receives a new arrival — a request bouncing back after a chunk (scheduler
re-``add``/``update``) never re-triggers it.
"""
from __future__ import annotations

import heapq
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.core.policies import PrefillQueue
from repro.core.request import Request
from repro.tenancy.admission import AdmissionController
from repro.tenancy.vtc import VirtualTokenCounter


class FairPrefillQueue:
    def __init__(
        self,
        policy_factory: Callable[[], PrefillQueue],
        vtc: VirtualTokenCounter,
        *,
        admission: Optional[AdmissionController] = None,
        extra_active_fn: Optional[Callable[[], Iterable[str]]] = None,
    ):
        self._policy_factory = policy_factory
        self.vtc = vtc
        self.admission = admission
        self._extra_active_fn = extra_active_fn
        self._queues: Dict[str, PrefillQueue] = {}
        self._owned: Dict[int, str] = {}        # req_id -> tenant (queued or mid-prefill)
        self._inflight: Dict[str, int] = {}     # tenant -> owned request count
        # ``queue`` admission policy holding pen: (ready_at, req_id, req)
        self._delayed: List[Tuple[float, int, Request]] = []
        self.now = 0.0                          # scheduler clock (penalty expiry)
        # SLO tier (FairnessState.attach_slo): urgency_fn(head_req, now) ->
        # bool.  A tenant whose HEAD request is deadline-urgent jumps the
        # VTC order (FairBatching-style SLO-driven batch formation); among
        # urgent tenants — and always when unset — VTC order still rules.
        self.urgency_fn: Optional[Callable[[Optional[Request], float], bool]] = None

    # -- clock ----------------------------------------------------------------
    def set_now(self, now: float) -> None:
        self.now = now
        while self._delayed and self._delayed[0][0] <= now:
            _, _, req = heapq.heappop(self._delayed)
            self._subqueue(req.tenant).add(req)

    # -- delayed admission (queue policy) --------------------------------------
    def add_delayed(self, req: Request, ready_at: float) -> None:
        """Park an over-budget request until its tenant's bucket refills.
        Ownership starts immediately (the tenant counts as active — rate-
        limited work must not bank idle credit), but the request only enters
        its subqueue once ``set_now`` passes ``ready_at``."""
        if ready_at <= self.now:
            self.add(req)
            return
        t = req.tenant
        if req.req_id not in self._owned:
            active = self._active_tenants()
            if t not in active:
                self.vtc.on_activate(t, active)
            self._owned[req.req_id] = t
            self._inflight[t] = self._inflight.get(t, 0) + 1
        heapq.heappush(self._delayed, (ready_at, req.req_id, req))

    def delayed_count(self) -> int:
        return len(self._delayed)

    def is_delayed(self, req: Request) -> bool:
        return any(rid == req.req_id for _, rid, _ in self._delayed)

    def next_ready_at(self) -> Optional[float]:
        return self._delayed[0][0] if self._delayed else None

    # -- helpers --------------------------------------------------------------
    def _subqueue(self, tenant: str) -> PrefillQueue:
        q = self._queues.get(tenant)
        if q is None:
            q = self._policy_factory()
            self._queues[tenant] = q
        return q

    def _active_tenants(self) -> set:
        active = {t for t, n in self._inflight.items() if n > 0}
        if self._extra_active_fn is not None:
            active |= set(self._extra_active_fn())
        return active

    def _select_tenant(self) -> Optional[str]:
        best = None
        best_key = None
        for t, q in self._queues.items():
            if len(q) == 0:
                continue
            penalized = (
                self.admission.is_penalized(t, self.now)
                if self.admission is not None
                else False
            )
            urgent = (
                bool(self.urgency_fn(q.peek(), self.now))
                if self.urgency_fn is not None
                else False
            )
            # `not urgent` is the constant True when no urgency_fn is
            # attached — ordering then reduces to (penalized, vtc, t),
            # bit-identical to the SLO-less queue
            key = (penalized, not urgent, self.vtc.virtual_service(t), t)
            if best_key is None or key < best_key:
                best, best_key = t, key
        return best

    # -- PrefillQueue interface ------------------------------------------------
    def __len__(self) -> int:
        # delayed requests count as queued work (has_work must stay true while
        # the pen drains) even though pop() skips them until they are ready
        return sum(len(q) for q in self._queues.values()) + len(self._delayed)

    def __contains__(self, req: Request) -> bool:
        t = self._owned.get(req.req_id)
        if t is None:
            return False
        q = self._queues.get(t)     # absent if the tenant's first request is
        return (q is not None and req in q) or any(  # still in the delay pen
            r.req_id == req.req_id for _, _, r in self._delayed
        )

    def add(self, req: Request) -> None:
        t = req.tenant
        if req.req_id not in self._owned:       # genuinely new arrival
            active = self._active_tenants()
            if t not in active:
                self.vtc.on_activate(t, active)
            self._owned[req.req_id] = t
            self._inflight[t] = self._inflight.get(t, 0) + 1
        self._subqueue(t).add(req)

    def update(self, req: Request) -> None:
        self._subqueue(req.tenant).update(req)
        if req.req_id not in self._owned:       # defensive: treat as add
            self._owned[req.req_id] = req.tenant
            self._inflight[req.tenant] = self._inflight.get(req.tenant, 0) + 1

    def remove(self, req: Request) -> None:
        t = self._owned.get(req.req_id)
        if t is None:
            return
        if t in self._queues:
            self._queues[t].remove(req)
        self._delayed = [e for e in self._delayed if e[1] != req.req_id]
        heapq.heapify(self._delayed)
        self.retire(req)

    def retire(self, req: Request) -> None:
        """Release ownership once a request's prefill completed (or it was
        dropped): the tenant stops counting as prefill-active for lifts."""
        t = self._owned.pop(req.req_id, None)
        if t is not None:
            self._inflight[t] = max(0, self._inflight.get(t, 0) - 1)

    def pop(self) -> Optional[Request]:
        t = self._select_tenant()
        if t is None:
            return None
        return self._queues[t].pop()            # popped but still owned

    def peek(self) -> Optional[Request]:
        t = self._select_tenant()
        if t is None:
            return None
        return self._queues[t].peek()

    def drain_sorted(self) -> List[Request]:
        out = []
        while True:
            r = self.pop()
            if r is None:
                return out
            out.append(r)

    def requests(self) -> Iterable[Request]:
        out: List[Request] = []
        for q in self._queues.values():
            out.extend(q.requests())
        out.extend(r for _, _, r in self._delayed)
        return out

    # -- introspection ---------------------------------------------------------
    def backlog_by_tenant(self) -> Dict[str, int]:
        return {t: len(q) for t, q in self._queues.items() if len(q) > 0}
