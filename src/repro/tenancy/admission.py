"""Token-bucket admission control with a deprioritization-penalty window.

Two-stage design (cf. the llmserve fairshare exemplar):

  1. ``assess(req)`` at submission refills the tenant's bucket and charges
     the request's estimated cost (prompt + max_new_tokens).  Within budget:
     clean admit.  Over budget, the configured policy applies:
       * ``deprioritize`` (default) — the request is still admitted, but the
         tenant enters a penalty window: the fair queue serves penalized
         tenants only when no unpenalized tenant has work.  Non-blocking,
         work-conserving, and self-healing once the bucket refills.
       * ``reject`` — the request is refused outright (hard quota).
       * ``queue`` — the request is admitted but DELAYED: the bucket is
         charged into debt and the request only becomes schedulable at the
         time the debt clears, so a tenant's queued work drains at exactly
         its contracted token rate (no loss, no priority inversion).
  2. The penalty expires on its own (``penalty_window_s`` after the last
     violation); ``is_penalized(tenant, now)`` is the query the fair queue
     uses at pop time.

Buckets use the scheduler's clock (request arrival times / round ``now``),
not wall time, so behavior is identical under the simulator and the real
engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.request import Request
from repro.tenancy.tenants import TenantRegistry, TenantSpec


@dataclass
class TokenBucket:
    rate: float                 # tokens per second
    burst: float                # bucket depth
    tokens: float               # current fill
    last_ts: float = 0.0

    def refill(self, now: float) -> None:
        if self.rate <= 0:
            return
        dt = now - self.last_ts
        if dt > 0:
            self.tokens = min(self.burst, self.tokens + self.rate * dt)
            self.last_ts = now

    def consume(self, cost: float, now: float) -> float:
        """Take up to ``cost`` tokens; returns the unmet deficit (>= 0)."""
        self.refill(now)
        take = min(self.tokens, cost)
        self.tokens -= take
        return cost - take

    def consume_debt(self, cost: float, now: float) -> float:
        """Charge ``cost`` unconditionally (the fill may go negative) and
        return the time the bucket is back at zero — the earliest moment the
        charged work is within budget.  Successive debts stack, so queued
        requests drain at exactly the contracted rate."""
        self.refill(now)
        self.tokens -= cost
        if self.tokens >= 0:
            return now
        return now + (-self.tokens) / self.rate


@dataclass(frozen=True)
class AdmissionDecision:
    tenant: str
    admitted: bool
    penalized: bool
    deficit: float = 0.0
    penalty_expires_at: float = 0.0
    delayed: bool = False       # queue policy: hold until ready_at
    ready_at: float = 0.0
    shed: bool = False          # SLO tier: deadline projected infeasible


@dataclass
class AdmissionStats:
    assessed: int = 0
    admitted: int = 0
    rejected: int = 0
    penalties: int = 0          # violations that opened/extended a window
    queued: int = 0             # requests delayed until bucket refill
    shed: int = 0               # SLO load shedding at admission


class AdmissionController:
    def __init__(
        self,
        registry: TenantRegistry,
        *,
        policy: str = "deprioritize",
        penalty_window_s: float = 2.0,
    ):
        if policy not in ("deprioritize", "reject", "queue"):
            raise ValueError(f"unknown admission policy {policy!r}")
        self.registry = registry
        self.policy = policy
        self.penalty_window_s = penalty_window_s
        self._buckets: Dict[str, TokenBucket] = {}
        self._penalty_until: Dict[str, float] = {}
        self.stats = AdmissionStats()
        # SLO tier (FairnessState.attach_slo): feasibility gate called as
        # slo_gate(req, now) -> bool; False sheds the request at admission —
        # the "reject when the deadline is unattainable" leg of load shedding
        self.slo_gate = None

    def _bucket(self, spec: TenantSpec, now: float) -> TokenBucket:
        b = self._buckets.get(spec.name)
        if b is None:
            # fresh buckets start full: a tenant may spend its burst at once
            b = TokenBucket(
                rate=spec.rate_tokens_per_s,
                burst=spec.effective_burst,
                tokens=spec.effective_burst,
                last_ts=now,
            )
            self._buckets[spec.name] = b
        return b

    @staticmethod
    def request_cost(req: Request) -> float:
        # submission-time estimate: full prompt + declared generation budget
        return float(req.prompt_len + req.max_new_tokens)

    def assess(self, req: Request, now: float = None) -> AdmissionDecision:
        if now is None:
            now = req.arrival_time
        self.stats.assessed += 1
        if self.slo_gate is not None and not self.slo_gate(req, now):
            # infeasible deadline: shedding now is strictly better than
            # admitting work that must miss — no bucket charge, no penalty
            self.stats.shed += 1
            return AdmissionDecision(
                tenant=req.tenant, admitted=False, penalized=False, shed=True
            )
        spec = self.registry.get(req.tenant)
        if spec.rate_tokens_per_s <= 0:          # unlimited tenant
            self.stats.admitted += 1
            return AdmissionDecision(tenant=req.tenant, admitted=True, penalized=False)

        bucket = self._bucket(spec, now)
        if self.policy == "queue":
            # delay-until-refill: charge the bucket into debt; the request is
            # admitted but only becomes schedulable once the debt clears
            ready_at = bucket.consume_debt(self.request_cost(req), now)
            self.stats.admitted += 1
            if ready_at <= now:
                return AdmissionDecision(
                    tenant=req.tenant, admitted=True, penalized=False
                )
            self.stats.queued += 1
            return AdmissionDecision(
                tenant=req.tenant, admitted=True, penalized=False,
                delayed=True, ready_at=ready_at,
            )

        deficit = bucket.consume(self.request_cost(req), now)
        if deficit <= 0:
            self.stats.admitted += 1
            return AdmissionDecision(tenant=req.tenant, admitted=True, penalized=False)

        if self.policy == "reject":
            self.stats.rejected += 1
            return AdmissionDecision(
                tenant=req.tenant, admitted=False, penalized=False, deficit=deficit
            )

        expires = now + self.penalty_window_s
        self._penalty_until[req.tenant] = max(
            self._penalty_until.get(req.tenant, 0.0), expires
        )
        self.stats.admitted += 1
        self.stats.penalties += 1
        return AdmissionDecision(
            tenant=req.tenant, admitted=True, penalized=True,
            deficit=deficit, penalty_expires_at=expires,
        )

    def is_penalized(self, tenant: str, now: float) -> bool:
        return self._penalty_until.get(tenant, 0.0) > now

    def penalty_expires_at(self, tenant: str) -> float:
        return self._penalty_until.get(tenant, 0.0)
