"""Tenant registry and fairness configuration.

A *tenant* is the multi-tenant unit of fairness: an API client, an
organization, or a traffic class.  The paper's Aging policy is fair across
REQUESTS; the tenancy subsystem layers fairness across TENANTS on top of it
(FairBatching / VTC-style), so one heavy client cannot starve the rest even
when every individual request is aged correctly.

``TenantSpec`` carries the per-tenant knobs: a weight (proportional share of
service), an optional token-bucket rate limit (tokens/s + burst), and
optional TTFT/E2E latency SLOs (reported always; enforced by the scheduler
when ``SchedulerConfig.slo`` is set).  ``TenantRegistry`` resolves specs at
runtime and — by default — auto-registers unknown tenants with weight 1 so
untagged traffic keeps working.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

DEFAULT_TENANT = "default"


@dataclass(frozen=True)
class TenantSpec:
    name: str
    weight: float = 1.0                    # proportional service share (>0)
    rate_tokens_per_s: float = 0.0         # token-bucket rate; 0 = unlimited
    burst_tokens: float = 0.0              # bucket depth; 0 = 2x rate
    # latency SLOs.  Reporting gauges always; with ``SchedulerConfig.slo``
    # set they additionally DRIVE the scheduler (deadline-aware LPRS,
    # SLO-weighted victim selection, APC protection, load shedding).
    ttft_slo_s: Optional[float] = None     # time-to-first-token target
    e2e_slo_s: Optional[float] = None      # end-to-end completion target
    # KV-cache quota as a fraction of the block pool this tenant may PIN at
    # once (None = unlimited).  Enforced by KVBlockPool at allocation and at
    # prefix-cache acquisition; over-quota chunks are deferred or trigger
    # same-tenant preemption, never other tenants'.
    kv_quota_frac: Optional[float] = None

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")
        if self.rate_tokens_per_s < 0 or self.burst_tokens < 0:
            raise ValueError(f"tenant {self.name!r}: negative rate/burst")
        if self.kv_quota_frac is not None and not (0.0 < self.kv_quota_frac <= 1.0):
            raise ValueError(
                f"tenant {self.name!r}: kv_quota_frac must be in (0, 1]"
            )

    @property
    def effective_burst(self) -> float:
        if self.burst_tokens > 0:
            return self.burst_tokens
        return 2.0 * self.rate_tokens_per_s


@dataclass(frozen=True)
class FairnessConfig:
    """Switchboard for the tenancy subsystem (``SchedulerConfig.fairness``).

    ``None`` (the default on SchedulerConfig) disables the subsystem entirely:
    the scheduler keeps the paper's single-level prefill queue, byte-identical
    behavior.
    """

    tenants: Tuple[TenantSpec, ...] = ()
    auto_register: bool = True             # unknown tenants get weight-1 specs
    # VTC charge weights: decode tokens cost more than prefill tokens per
    # token (memory-bound vs compute-bound), mirroring the VTC paper's
    # (w_p, w_q) = (1, 2) default.
    prefill_charge_weight: float = 1.0
    decode_charge_weight: float = 2.0
    # token-bucket admission control:
    #   * deprioritize — admit, but serve the tenant last until the window ends
    #   * reject       — refuse over-budget requests outright (hard quota)
    #   * queue        — delay the request until the bucket refills (the
    #                    ROADMAP's "delay instead of deprioritize/reject")
    admission: bool = True
    admission_policy: str = "deprioritize"  # "deprioritize" | "reject" | "queue"
    penalty_window_s: float = 2.0           # deprioritization window length

    def __post_init__(self):
        if self.admission_policy not in ("deprioritize", "reject", "queue"):
            raise ValueError(f"unknown admission_policy {self.admission_policy!r}")
        names = [t.name for t in self.tenants]
        if len(names) != len(set(names)):
            raise ValueError("duplicate tenant names in FairnessConfig")


class TenantRegistry:
    """Name -> TenantSpec resolution with optional auto-registration."""

    def __init__(self, specs: Tuple[TenantSpec, ...] = (), *, auto_register: bool = True):
        self._specs: Dict[str, TenantSpec] = {s.name: s for s in specs}
        self.auto_register = auto_register

    def register(self, spec: TenantSpec) -> None:
        self._specs[spec.name] = spec

    def get(self, name: str) -> TenantSpec:
        spec = self._specs.get(name)
        if spec is None:
            spec = TenantSpec(name=name)
            if self.auto_register:
                self._specs[name] = spec
        return spec

    def weight(self, name: str) -> float:
        return self.get(name).weight

    def weights(self) -> Dict[str, float]:
        return {n: s.weight for n, s in self._specs.items()}

    def names(self) -> Tuple[str, ...]:
        return tuple(self._specs)

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __iter__(self) -> Iterator[TenantSpec]:
        return iter(self._specs.values())

    def __len__(self) -> int:
        return len(self._specs)
