"""Weighted Virtual Token Counter (VTC) — per-tenant service accounting.

Each tenant carries a *virtual service* counter u_t.  When the engine
executes a batch, every tenant is charged for the tokens it actually
received:

    u_t += (w_p * prefill_tokens + w_q * decode_tokens) / weight_t

The inter-tenant scheduler always serves the backlogged tenant with the
LOWEST virtual service, which converges to weighted max-min fair service
(tenant t receives service proportional to weight_t while backlogged).

Charging happens post-execution (``ChunkedPrefillScheduler.on_batch_done``)
so the counter reflects tokens actually delivered — a chunk trimmed or
blocked by APC is never charged.

The *lift* rule (``on_activate``) prevents idle-credit banking: a tenant
that was idle re-enters at ``max(own, min over active tenants)`` instead of
keeping a stale low counter that would let it monopolize the engine to
"catch up" on service it never queued for.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Optional

from repro.tenancy.tenants import TenantRegistry


@dataclass
class TenantService:
    """Raw (unweighted) accounting for one tenant, for reports/invariants."""

    prefill_tokens: int = 0
    decode_tokens: int = 0
    charges: int = 0                       # number of charge events
    lifted: float = 0.0                    # total virtual service added by lifts

    @property
    def actual_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens


class VirtualTokenCounter:
    def __init__(
        self,
        registry: TenantRegistry,
        *,
        prefill_weight: float = 1.0,
        decode_weight: float = 2.0,
    ):
        self.registry = registry
        self.prefill_weight = prefill_weight
        self.decode_weight = decode_weight
        self._virtual: Dict[str, float] = {}
        self._service: Dict[str, TenantService] = {}

    # -- accounting -----------------------------------------------------------
    def charge(self, tenant: str, prefill_tokens: int, decode_tokens: int) -> float:
        """Charge executed tokens; returns the virtual-service increment."""
        if prefill_tokens < 0 or decode_tokens < 0:
            raise ValueError("negative token charge")
        if prefill_tokens == 0 and decode_tokens == 0:
            return 0.0
        w = self.registry.weight(tenant)
        inc = (
            self.prefill_weight * prefill_tokens
            + self.decode_weight * decode_tokens
        ) / w
        self._virtual[tenant] = self._virtual.get(tenant, 0.0) + inc
        svc = self._service.setdefault(tenant, TenantService())
        svc.prefill_tokens += prefill_tokens
        svc.decode_tokens += decode_tokens
        svc.charges += 1
        return inc

    def refund(self, tenant: str, prefill_tokens: int = 0,
               decode_tokens: int = 0) -> float:
        """Refund charged tokens whose work was DISCARDED before delivery (a
        crashed round's undrained placeholders, a quarantined non-finite
        sample).  The inverse of ``charge``: the tenant's virtual service and
        raw counters both come back down, keeping fleet-wide charge equal to
        executed-and-surviving work.  Never use it for delivered tokens —
        streamed output is irrevocable and its service was really rendered."""
        if prefill_tokens < 0 or decode_tokens < 0:
            raise ValueError("negative token refund")
        if prefill_tokens == 0 and decode_tokens == 0:
            return 0.0
        w = self.registry.weight(tenant)
        dec = (
            self.prefill_weight * prefill_tokens
            + self.decode_weight * decode_tokens
        ) / w
        self._virtual[tenant] = self._virtual.get(tenant, 0.0) - dec
        svc = self._service.setdefault(tenant, TenantService())
        svc.prefill_tokens -= prefill_tokens
        svc.decode_tokens -= decode_tokens
        assert svc.prefill_tokens >= 0 and svc.decode_tokens >= 0, (
            f"refund exceeds charged service for tenant {tenant!r}"
        )
        return dec

    def on_activate(self, tenant: str, active: Iterable[str]) -> None:
        """Lift a (re)activating tenant's counter to the active floor.

        ``active`` is the set of tenants currently holding queued or running
        work, EXCLUDING ``tenant`` itself.  With no active peers there is no
        service to be fair against and the counter is left untouched.
        """
        floor: Optional[float] = None
        for t in active:
            if t == tenant:
                continue
            v = self._virtual.get(t, 0.0)
            floor = v if floor is None else min(floor, v)
        if floor is None:
            return
        own = self._virtual.get(tenant, 0.0)
        if floor > own:
            self._service.setdefault(tenant, TenantService()).lifted += floor - own
            self._virtual[tenant] = floor

    # -- views ---------------------------------------------------------------
    def virtual_service(self, tenant: str) -> float:
        return self._virtual.get(tenant, 0.0)

    def service(self, tenant: str) -> TenantService:
        return self._service.get(tenant, TenantService())

    def actual_tokens(self, tenant: str) -> int:
        return self.service(tenant).actual_tokens

    def tenants(self) -> Iterable[str]:
        return self._virtual.keys()

    def total_actual_tokens(self) -> int:
        return sum(s.actual_tokens for s in self._service.values())

    def total_prefill_tokens(self) -> int:
        return sum(s.prefill_tokens for s in self._service.values())

    def total_decode_tokens(self) -> int:
        return sum(s.decode_tokens for s in self._service.values())

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {
            t: {
                "virtual": self._virtual.get(t, 0.0),
                "prefill_tokens": s.prefill_tokens,
                "decode_tokens": s.decode_tokens,
                "lifted": s.lifted,
            }
            for t, s in self._service.items()
        }
