"""Multi-pod serving/training dry-run for one architecture:

  PYTHONPATH=src python examples/multipod_dryrun.py --arch mixtral-8x7b

Lowers and compiles every applicable (shape) cell of the chosen arch on the
single-pod (16x16) AND multi-pod (2x16x16) production meshes, printing the
roofline terms — the exact machinery behind EXPERIMENTS.md §Dry-run.

NOTE: must run as its own process (device count is forced to 512 before jax
initializes, via repro.launch.dryrun's import-time XLA_FLAGS).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import dryrun  # noqa: E402  (sets XLA_FLAGS first)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    args = ap.parse_args()
    return dryrun.main(["--arch", args.arch, "--both", "--quiet"])


if __name__ == "__main__":
    raise SystemExit(main())
