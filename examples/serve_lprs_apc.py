"""End-to-end serving driver (the paper's full system, §3.2-3.3):

  PYTHONPATH=src python examples/serve_lprs_apc.py

1. PROFILE: run the static token-budget scheduler on a real JAX engine and
   record (16-dim features, wall-clock ms) per round — §3.2.1's offline
   pipeline on this machine's own latencies.
2. TRAIN the MLP latency predictor (asymmetric Huber).
3. SERVE with LPRS (target-latency chunk search, Algorithm 1) + APC
   (activity cap / min progress, Eqs. 12-14) and compare against the
   static-budget baseline on the same workload.
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs import tiny_config
from repro.core.apc import APCConfig
from repro.core.lprs import LPRSConfig
from repro.core.predictor import LatencyPredictor, PredictorConfig, bucket_and_downsample
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.workload import WorkloadSpec, attach_prompt_tokens, sharegpt_like

MODEL = "qwen1.5-0.5b"


def make_workload(n, seed):
    reqs = sharegpt_like(WorkloadSpec(
        n_requests=n, inter_arrival_s=0.01, max_context=256,
        max_new_tokens=16, seed=seed,
    ))
    attach_prompt_tokens(reqs, tiny_config(MODEL).vocab_size, seed=seed)
    return reqs


def main():
    cfg = tiny_config(MODEL)
    engine = JAXEngine(cfg, EngineConfig(n_slots=8, max_context=512))
    engine.warmup()     # compile bucket shapes so profiling is steady-state

    # -- 1. profile under the static budget --------------------------------
    print("1) profiling real round latencies under the static budget ...")
    sched = ChunkedPrefillScheduler(SchedulerConfig(
        policy="fcfs", token_budget=96, max_seqs=8,
    ))
    feats, lats = [], []
    for seed in range(3):
        prof = serve(make_workload(32, seed=100 + seed), sched, engine,
                     collect_samples=True)
        feats.append(prof.samples[0])
        lats.append(prof.samples[1])
    feats, lats = np.concatenate(feats), np.concatenate(lats)
    # clean: drop wall-clock outliers (GC pauses etc.), per §3.2.1 step 3
    ok = lats < 5 * np.median(lats)
    feats, lats = feats[ok], lats[ok]
    print(f"   {len(lats)} rounds, latency p50={np.median(lats):.1f} ms "
          f"p90={np.percentile(lats, 90):.1f} ms")

    # -- 2. train the predictor --------------------------------------------
    print("2) training the latency predictor (asymmetric Huber) ...")
    keep, w = bucket_and_downsample(feats[:, 12])
    predictor = LatencyPredictor(PredictorConfig(epochs=200, dropout=0.0))
    predictor.fit(feats[keep], lats[keep], sample_weights=w)
    print(f"   eval: {predictor.evaluate(feats, lats)}")

    # -- 3. serve: static budget vs LPRS+APC --------------------------------
    target = float(np.percentile(lats, 60))
    print(f"3) serving with LPRS (T*={target:.1f} ms) + APC vs static budget")
    results = {}
    for label, lprs, apc in (
        ("static", None, None),
        ("lprs+apc", LPRSConfig(target_latency_ms=target, search_delta=16),
         APCConfig(c_max=2, l_min=16)),
    ):
        sched = ChunkedPrefillScheduler(
            SchedulerConfig(policy="aging", alpha=1.0, beta=-0.1,
                            token_budget=96, max_seqs=8, lprs=lprs, apc=apc),
            predictor=predictor if lprs else None,
        )
        res = serve(make_workload(16, seed=1), sched, engine,
                    collect_samples=True)
        row = res.report.row()
        _, round_lats = res.samples
        over = float(np.mean(round_lats > target))
        results[label] = (row, over)
        print(f"   {label:9s} finished {res.report.n_finished}/16 | "
              f"P99 e2e {row['p99_e2e'] * 1e3:7.1f} ms | round>T* {over:.0%}"
              + (f" | apc blocks {sched.stats.apc.blocked_by_min_chunk + sched.stats.apc.blocked_by_cap}"
                 if apc else ""))

    s_over = results["static"][1]
    l_over = results["lprs+apc"][1]
    print(f"\nrounds exceeding the {target:.0f} ms target: "
          f"static {s_over:.0%} -> LPRS {l_over:.0%} "
          "(LPRS trades fill for latency controllability)")


if __name__ == "__main__":
    main()
