"""Train a ~tiny model end-to-end with the production training stack:

  PYTHONPATH=src python examples/train_tiny.py [--arch mixtral-8x7b]

sharded init -> synthetic data pipeline -> jitted train_step (remat,
grad-accum) -> async checkpointing -> kill/resume demonstration.
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    help="any assigned arch id (tiny variant is used)")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        print(f"== training tiny {args.arch} for {args.steps} steps "
              f"(ckpt every 20 into {d}) ==")
        _, losses = train(
            args.arch, steps=args.steps, global_batch=8, seq_len=64,
            ckpt_dir=d, ckpt_every=20, log_every=10, n_microbatches=2,
        )
        print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f}")

        print("\n== simulated failure: resuming from the last checkpoint ==")
        _, tail = train(
            args.arch, steps=args.steps + 20, global_batch=8, seq_len=64,
            ckpt_dir=d, ckpt_every=20, log_every=10, n_microbatches=2,
            resume=True,
        )
        print(f"\nresumed loss: {tail[0]:.3f} -> {tail[-1]:.3f}")


if __name__ == "__main__":
    main()
