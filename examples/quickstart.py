"""Quickstart: the paper's full stack in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. Build a tiny llama-family model and a real chunked-prefill JAX engine.
2. Serve a mixed workload under FCFS, then under Aging (§3.1).
3. Compare TTFT/E2E — Aging reorders prefills, execution is identical.
"""
import sys

sys.path.insert(0, "src")

from repro.configs import tiny_config
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.workload import WorkloadSpec, attach_prompt_tokens, sharegpt_like


def run(policy: str) -> dict:
    cfg = tiny_config("llama3.2-1b")
    engine = JAXEngine(cfg, EngineConfig(n_slots=8, max_context=512))

    # a short/long mixed workload (ShareGPT-like skew), real token ids
    requests = sharegpt_like(WorkloadSpec(
        n_requests=16, inter_arrival_s=0.02, max_context=256,
        max_new_tokens=24, seed=0,
    ))
    attach_prompt_tokens(requests, cfg.vocab_size)

    scheduler = ChunkedPrefillScheduler(SchedulerConfig(
        policy=policy,          # "fcfs" | "sjf" | "aging"
        alpha=1.0, beta=-0.1,   # aging: P_i = alpha*(wait) + beta*(remaining)
        token_budget=64,        # B_max per scheduling round
        max_seqs=8,
    ))
    result = serve(requests, scheduler, engine)
    row = result.report.row()
    print(f"{policy:6s}: finished {result.report.n_finished}/16 "
          f"in {result.wall_s:.2f}s | mean TTFT {row['mean_ttft'] * 1e3:7.1f} ms "
          f"| mean E2E {row['mean_e2e'] * 1e3:7.1f} ms")
    return row


if __name__ == "__main__":
    print("serving 16 mixed requests on a tiny llama with real JAX execution\n")
    fcfs = run("fcfs")
    aging = run("aging")
    d = 100 * (aging["mean_ttft"] - fcfs["mean_ttft"]) / fcfs["mean_ttft"]
    print(f"\nAging vs FCFS mean TTFT: {d:+.1f}% "
          "(negative = fairness-aware ordering helped)")
