"""Multi-tenant serving on the real JAX engine:

  PYTHONPATH=src python examples/serve_multitenant.py

One heavy tenant (long prompts, high rate) and two light tenants share a
tiny Qwen engine.  The run is executed twice over the same trace — once with
the paper's Aging scheduler alone, once with the tenancy subsystem on top
(weighted VTC + token-bucket admission) — and the per-tenant TTFT and
Jain's fairness index are compared.
"""
import sys

sys.path.insert(0, "src")


from repro.configs import tiny_config
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.metrics import summarize_by_tenant
from repro.engine.workload import TenantTraffic, attach_prompt_tokens, multi_tenant
from repro.tenancy import FairnessConfig, TenantSpec

MODEL = "qwen1.5-0.5b"

TENANTS = (
    TenantSpec("bulk", weight=1.0, rate_tokens_per_s=400.0, burst_tokens=800.0),
    TenantSpec("chat-a", weight=2.0),
    TenantSpec("chat-b", weight=2.0),
)


def make_workload(seed: int):
    reqs = multi_tenant(
        [
            TenantTraffic("bulk", "heavy", rps=40.0, prompt_mean=128.0,
                          max_new_tokens=8),
            TenantTraffic("chat-a", "light", rps=4.0, prompt_mean=24.0,
                          max_new_tokens=8),
            TenantTraffic("chat-b", "light", rps=4.0, prompt_mean=24.0,
                          max_new_tokens=8),
        ],
        duration_s=2.0, max_context=192, seed=seed,
    )
    attach_prompt_tokens(reqs, tiny_config(MODEL).vocab_size, seed=seed)
    return reqs


def main():
    cfg = tiny_config(MODEL)
    # enough slots that the scheduler, not FCFS slot admission, decides order
    engine = JAXEngine(cfg, EngineConfig(n_slots=32, max_context=256))
    engine.warmup()

    results = {}
    for label, fairness in (
        ("aging", None),
        ("aging+tenancy", FairnessConfig(tenants=TENANTS)),
    ):
        sched = ChunkedPrefillScheduler(SchedulerConfig(
            policy="aging", alpha=1.0, beta=-0.01,
            token_budget=96, max_seqs=32, fairness=fairness,
        ))
        res = serve(make_workload(seed=0), sched, engine)
        rep = summarize_by_tenant(
            res.requests, weights={t.name: t.weight for t in TENANTS},
        )
        results[label] = rep
        print(f"\n== {label}: {res.report.n_finished}/{res.report.n_total} "
              f"finished in {res.wall_s:.1f}s, {res.rounds} rounds")
        for t, r in rep.per_tenant.items():
            print(f"   {t:8s} n={r.n_total:3d} mean TTFT {r.ttft['mean'] * 1e3:7.1f} ms"
                  f" | p95 {r.ttft['p95'] * 1e3:7.1f} ms"
                  f" | service {rep.service_tokens[t]:7.0f} tok")
        print(f"   Jain (weight-normalized service): {rep.jain:.3f}")
        if fairness is not None:
            snap = sched.fairness.vtc.snapshot()
            print("   VTC: " + ", ".join(
                f"{t}: virtual={s['virtual']:.0f}" for t, s in sorted(snap.items())
            ))
            if sched.fairness.admission is not None:
                st = sched.fairness.admission.stats
                print(f"   admission: {st.assessed} assessed, "
                      f"{st.penalties} penalties, {st.rejected} rejected")

    base, fair = results["aging"], results["aging+tenancy"]
    chat_base = max(base.per_tenant[t].ttft["p95"] for t in ("chat-a", "chat-b"))
    chat_fair = max(fair.per_tenant[t].ttft["p95"] for t in ("chat-a", "chat-b"))
    print(f"\nworst chat-tenant P95 TTFT: {chat_base * 1e3:.0f} ms (aging) -> "
          f"{chat_fair * 1e3:.0f} ms (aging+tenancy) | "
          f"Jain {base.jain:.3f} -> {fair.jain:.3f}")


if __name__ == "__main__":
    main()
